"""Benchmark entrypoint: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Sections:
  table2  — accuracy vs Baseline for every strategy (paper Table 2)
  table3  — Grad-Match comparison, single worker (paper Table 3)
  table5  — prediction-confidence threshold sweep (paper Table 5)
  table6  — HE/MB/RF/LR component ablation (paper Table 6)
  fig2    — convergence/speedup (paper Fig. 2)
  fig4    — hiding-fraction evolution (paper Fig. 4)
  selection — selection-overhead microbench (paper Table 1 complexity row)
  kernels — Pallas kernel micro timings
  roofline — dry-run roofline table (if results/dryrun_roofline exists)
"""
import sys

from benchmarks import (fig2_speedup, fig4_fraction, kernel_micro, roofline,
                        selection_overhead, table2_accuracy, table3_gradmatch,
                        table5_tau, table6_ablation)

SECTIONS = {
    "table2": table2_accuracy.main,
    "table3": table3_gradmatch.main,
    "table5": table5_tau.main,
    "table6": table6_ablation.main,
    "fig2": fig2_speedup.main,
    "fig4": fig4_fraction.main,
    "selection": selection_overhead.main,
    "kernels": kernel_micro.main,
    "roofline": roofline.main,
}


def main() -> None:
    only = sys.argv[1:] or list(SECTIONS)
    print("name,us_per_call,derived")
    for name in only:
        SECTIONS[name]()


if __name__ == "__main__":
    main()
