"""Paper Table 6: component ablation — HE / MB / RF / LR (v1000..v1111)."""
from repro.core import KakurenboConfig

from benchmarks.common import EPOCHS, csv_row, run_strategy


def main() -> None:
    base = run_strategy("baseline")
    print(csv_row("table6/baseline", base["wall_s"] / EPOCHS * 1e6,
                  f"best_acc={base['best_acc']:.4f}"))
    for mb in (False, True):
        for rf in (False, True):
            for lr in (False, True):
                tag = f"v1{int(mb)}{int(rf)}{int(lr)}"
                kc = KakurenboConfig(
                    max_fraction=0.4, moveback=mb, reduce_fraction=rf,
                    adjust_lr=lr, fraction_milestones=(0, 4, 6, 9))
                res = run_strategy("kakurenbo", kakurenbo=kc)
                print(csv_row(
                    f"table6/{tag}", res["wall_s"] / EPOCHS * 1e6,
                    f"best_acc={res['best_acc']:.4f};"
                    f"diff={res['best_acc'] - base['best_acc']:+.4f}"))


if __name__ == "__main__":
    main()
