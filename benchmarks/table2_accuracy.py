"""Paper Table 2: max test accuracy of Baseline / ISWR / FORGET / SB /
KAKURENBO (+ random hiding, App. C.4). Reports per-epoch time and the
accuracy delta vs Baseline."""
from benchmarks.common import EPOCHS, csv_row, run_strategy


def main() -> None:
    rows = []
    base = run_strategy("baseline")
    rows.append(("table2/baseline", base, 0.0))
    for strat in ("iswr", "forget", "sb", "kakurenbo", "random",
                  "infobatch"):
        res = run_strategy(strat)
        rows.append((f"table2/{strat}", res, res["best_acc"] - base["best_acc"]))
    for name, res, diff in rows:
        us_per_epoch = res["wall_s"] / EPOCHS * 1e6
        print(csv_row(name, us_per_epoch,
                      f"best_acc={res['best_acc']:.4f};diff={diff:+.4f};"
                      f"bwd_samples={res['bwd']}"))


if __name__ == "__main__":
    main()
