"""Paper Fig. 2: convergence + speedup — wall-clock to target accuracy and
backward-work reduction for KAKURENBO vs Baseline."""
from benchmarks.common import EPOCHS, csv_row, run_strategy


def _time_to_acc(res, target):
    t = 0.0
    for h in res["history"]:
        t += h.wall_time
        if h.test_acc >= target:
            return t
    return float("nan")


def main() -> None:
    base = run_strategy("baseline")
    kk = run_strategy("kakurenbo")
    target = 0.9 * base["best_acc"]
    for name, res in (("fig2/baseline", base), ("fig2/kakurenbo", kk)):
        tta = _time_to_acc(res, target)
        print(csv_row(name, res["wall_s"] / EPOCHS * 1e6,
                      f"time_to_{target:.2f}acc={tta:.1f}s;"
                      f"bwd_reduction={1 - res['bwd'] / base['bwd']:.3f};"
                      f"wall_reduction={1 - res['wall_s'] / base['wall_s']:.3f}"))


if __name__ == "__main__":
    main()
