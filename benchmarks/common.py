"""Shared harness for the paper-reproduction benchmarks.

All benchmarks train the paper's own model family (small conv classifier) on
the synthetic easy/hard classification dataset — the offline stand-in for
CIFAR/ImageNet (DESIGN.md Sec. 3) — and report relative accuracy/time deltas
against the Baseline, which is what the paper's tables claim.
"""
from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core import KakurenboConfig, LRSchedule, make_strategy
from repro.data import SyntheticClassification
from repro.models import cnn
from repro.train import Trainer, TrainConfig

MODEL_CFG = cnn.CNNConfig(image_size=16, widths=(16, 32), hidden=64)
NUM_SAMPLES = 1024
EPOCHS = 16
BATCH = 128


def model_fns():
    def init_params(rng):
        return cnn.init(rng, MODEL_CFG)

    def loss_fn(params, batch):
        logits = cnn.forward(params, MODEL_CFG, batch["images"])
        loss, pa, pc = cnn.per_sample_metrics(logits, batch["labels"])
        w = batch.get("weight")
        scalar = jnp.mean(loss * w) if w is not None else jnp.mean(loss)
        return scalar, (loss, pa, pc)

    def feats_fn(params, batch):
        """last-layer grad proxy for Grad-Match: p - onehot(y)."""
        logits = cnn.forward(params, MODEL_CFG, batch["images"])
        p = jnp.exp(logits - jnp.max(logits, -1, keepdims=True))
        p = p / p.sum(-1, keepdims=True)
        onehot = jnp.eye(MODEL_CFG.num_classes)[batch["labels"]]
        return p - onehot

    return init_params, loss_fn, feats_fn


def datasets(seed: int = 0):
    ds = SyntheticClassification(num_samples=NUM_SAMPLES, seed=seed)
    return ds, ds.test_split(512)


def run_strategy(strategy: str, *, epochs: int = EPOCHS, seed: int = 0,
                 kakurenbo: KakurenboConfig | None = None,
                 base_lr: float = 0.03, **cfg_kw):
    from repro.core import ForgetConfig
    ds, test = datasets(seed)
    init_params, loss_fn, feats_fn = model_fns()
    tc = TrainConfig(
        epochs=epochs, batch_size=BATCH, strategy=strategy,
        lr=LRSchedule(base_lr, "cosine", epochs, 1),
        kakurenbo=kakurenbo or KakurenboConfig(
            max_fraction=0.3,
            fraction_milestones=(0, epochs // 3, epochs // 2,
                                 3 * epochs // 4)),
        # FORGET warmup must fit inside the run so prune+restart happens;
        # the paper's 20-epoch warmup maps to 1/4 of our reduced schedule.
        forget=ForgetConfig(fraction=0.3, warmup_epochs=max(epochs // 4, 2)),
        seed=seed, **cfg_kw)
    # Resolve the strategy through the registry: benchmark rows are exactly
    # the registered names, so a new @register_strategy class shows up in
    # every table without touching the harness.
    strat = make_strategy(strategy, ds.num_samples, cfg=tc, seed=seed,
                          num_classes=MODEL_CFG.num_classes,
                          total_epochs=epochs)
    # feats_fn is lazy: only strategies whose prepare() asks for features
    # (Grad-Match) ever invoke it, so it is safe to wire up unconditionally.
    tr = Trainer(tc, init_params, loss_fn, ds, test, strategy=strat,
                 feats_fn=feats_fn)
    t0 = time.perf_counter()
    hist = tr.run()
    wall = time.perf_counter() - t0
    return {
        "history": hist,
        "wall_s": wall,
        "final_acc": hist[-1].test_acc,
        "best_acc": max(h.test_acc for h in hist if h.test_acc == h.test_acc),
        "fwd": sum(h.fwd_samples for h in hist),
        "bwd": sum(h.bwd_samples for h in hist),
    }


def csv_row(name: str, us_per_call: float, derived) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
