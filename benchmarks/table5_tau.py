"""Paper Table 5: impact of the prediction-confidence threshold tau."""
from repro.core import KakurenboConfig

from benchmarks.common import EPOCHS, csv_row, run_strategy


def main() -> None:
    for tau in (0.5, 0.7, 0.9):
        kc = KakurenboConfig(max_fraction=0.3, tau=tau,
                             fraction_milestones=(0, 4, 6, 9))
        res = run_strategy("kakurenbo", kakurenbo=kc)
        mean_hidden = sum(h.hidden_fraction for h in res["history"]) / EPOCHS
        print(csv_row(f"table5/tau={tau}", res["wall_s"] / EPOCHS * 1e6,
                      f"best_acc={res['best_acc']:.4f};"
                      f"mean_hidden={mean_hidden:.3f}"))


if __name__ == "__main__":
    main()
