"""Table 1 complexity row: selection cost — paper-faithful O(N log N) sort vs
the beyond-paper O(N) histogram threshold (+ its Pallas kernel)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import init_sample_state, scatter_observations, select_hidden
from benchmarks.common import csv_row


def _bench(fn, *args, iters=5):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main() -> None:
    for n in (100_000, 1_000_000):
        r = np.random.default_rng(0)
        s = init_sample_state(n)
        s = scatter_observations(
            s, jnp.arange(n), jnp.asarray(r.exponential(1, n), jnp.float32),
            jnp.ones(n, bool), jnp.full(n, 0.9, jnp.float32), 0)
        t_sort = _bench(lambda st: select_hidden(st, 0.3, method="sort"), s)
        t_hist = _bench(lambda st: select_hidden(st, 0.3, method="histogram"), s)
        print(csv_row(f"selection/sort_N{n}", t_sort, "method=argsort;O(NlogN)"))
        print(csv_row(f"selection/hist_N{n}", t_hist,
                      f"method=histogram;O(N);speedup={t_sort / t_hist:.2f}x"))


if __name__ == "__main__":
    main()
