"""Table 1 complexity row: selection/plan cost of the device-resident engine.

Three selection methods — paper-faithful O(N log N) ``sort``, the O(N)
histogram-CDF ``histogram`` and its Pallas-kernel twin ``histogram_pallas``
(interpret mode on this CPU container) — timed both as the raw jitted
``select_hidden`` and as the full jitted epoch plan step
(``KakurenboSampler.begin_epoch``: selection + move-back + device shuffle +
one host sync).

Also demonstrates the engine's host-sync contract by driving one simulated
epoch through both observation paths and counting SampleState host round
trips: legacy per-batch ``observe()`` pays batches+1, the fused path
(scatter inside the jitted train step) pays exactly 1.  And — now that
PlanOps moved every strategy's planning on device — ``strategy_sync_counts``
trains a real (tiny) epoch per *registered strategy* and asserts each one
plans with exactly 1 host sync/epoch under the scanned engine.

``--mesh`` switches to the mesh-sharded engine: an 8-device ``("data",)``
mesh (host-simulated; the flag is injected before jax initialises), the
SampleState row-sharded, and the cross-shard plan step — shard_map'd
histogram + O(bins) psum for the histogram methods, global GSPMD argsort
for ``sort``.  Emits sharded plan time and the per-epoch host-sync count
(still exactly 1).  Numbers are recorded in ``docs/benchmarks.md``.

Emits one ``BENCH {json}`` line per measurement (the perf-trajectory seed)
alongside the legacy CSV rows.
"""
import argparse
import json
import os
import sys
import time

# Must be set before jax picks a backend: --mesh simulates 8 host devices.
if "--mesh" in sys.argv:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    KakurenboConfig, KakurenboSampler, SELECTION_METHODS, init_sample_state,
    scatter_observations, select_hidden,
)
from repro.dist.sharding import ParallelCtx
from repro.launch.train import plan_summary
from benchmarks.common import csv_row


def _bench(fn, *args, iters=5):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _observed_state(n: int, seed: int = 0):
    r = np.random.default_rng(seed)
    s = init_sample_state(n)
    return scatter_observations(
        s, jnp.arange(n), jnp.asarray(r.exponential(1, n), jnp.float32),
        jnp.ones(n, bool), jnp.full(n, 0.9, jnp.float32), 0)


def _plan_time_us(n: int, method: str, iters: int = 5,
                  ctx: ParallelCtx | None = None) -> float:
    """Full epoch plan step (selection + shuffle + the 1 host sync).

    With a mesh ``ctx`` this is the cross-shard plan on a row-sharded
    SampleState (``ctx`` defaults to the off-mesh identity context)."""
    ctx = ctx or ParallelCtx()
    ks = KakurenboSampler(n, KakurenboConfig(selection=method), ctx=ctx)
    ks.state = ctx.shard_rows(_observed_state(n))
    ks.begin_epoch(0)  # compile
    t0 = time.perf_counter()
    for e in range(1, iters + 1):
        ks.begin_epoch(e)
    return (time.perf_counter() - t0) / iters * 1e6


def _epoch_sync_counts(n: int = 4096, batch: int = 256,
                       ctx: ParallelCtx | None = None) -> dict:
    """One simulated epoch through both observation paths; count SampleState
    host round trips (observe dispatches + the plan materialisation).
    Identical accounting on and off the mesh — the sharding must not change
    the host-sync contract."""
    ctx = ctx or ParallelCtx()
    r = np.random.default_rng(0)
    batches = [
        (np.arange(i, i + batch),
         jnp.asarray(r.exponential(1, batch), jnp.float32),
         jnp.ones(batch, bool), jnp.full(batch, 0.9, jnp.float32))
        for i in range(0, n, batch)
    ]

    legacy = KakurenboSampler(n, ctx=ctx)
    for idx, lv, pa, pc in batches:
        legacy.observe(idx, lv, pa, pc, 0)   # host dispatch per batch
    legacy.begin_epoch(1)

    fused = KakurenboSampler(n, ctx=ctx)
    step = jax.jit(scatter_observations, donate_argnums=0)
    state = fused.state                      # stays on device all epoch...
    for idx, lv, pa, pc in batches:
        state = step(state, jnp.asarray(idx), lv, pa, pc, 0)
    fused.state = state                      # ...handed back once
    plan = fused.begin_epoch(1)

    return {"batches": len(batches), "devices": ctx.dp_size,
            "host_syncs_legacy": legacy.host_round_trips,
            "host_syncs_fused": fused.host_round_trips,
            "plan": plan_summary(plan)}


def strategy_sync_counts(num_samples: int = 512, batch: int = 64,
                         epochs: int = 2,
                         guard_policy: str = "skip_update",
                         fused_scoring: bool = False) -> list[dict]:
    """One tiny training run per registered strategy: every strategy must
    auto-select the scanned engine and keep plan+loop host syncs at
    1/epoch — the PlanOps acceptance bar.  Runs with the numeric guard ON
    by default: its counters ride the device carry and the epoch-end fetch,
    so guarding must not add a single host sync.  ``fused_scoring=True``
    replays the sweep with the one-pass fused (loss, PA, PC) scoring
    (``TrainConfig.fused_scoring``) — the in-step scoring swap must not
    move a single observation to the host either."""
    import jax.numpy as jnp

    from repro.core import (
        ForgetConfig, LRSchedule, available_strategies,
    )
    from repro.data import SyntheticClassification
    from repro.models import cnn
    from repro.train import Trainer, TrainConfig

    model_cfg = cnn.CNNConfig(image_size=8, widths=(8,), hidden=16)

    def logits_fn(params, batch_):
        return cnn.forward(params, model_cfg, batch_["images"])

    def loss_fn(params, batch_):
        logits = logits_fn(params, batch_)
        loss, pa, pc = cnn.per_sample_metrics(logits, batch_["labels"])
        w = batch_.get("weight")
        scalar = jnp.mean(loss * w) if w is not None else jnp.mean(loss)
        return scalar, (loss, pa, pc)

    ds = SyntheticClassification(num_samples=num_samples, image_size=8,
                                 seed=0)
    records = []
    for name in available_strategies():
        tc = TrainConfig(
            epochs=epochs, batch_size=batch, strategy=name,
            kakurenbo=KakurenboConfig(selection="histogram", max_fraction=0.3,
                                      fraction_milestones=(0, 1, 2, 3)),
            forget=ForgetConfig(fraction=0.3, warmup_epochs=1),
            lr=LRSchedule(0.05, "cosine", epochs, 1), seed=0,
            guard_policy=guard_policy, fused_scoring=fused_scoring)
        tr = Trainer(tc, lambda r: cnn.init(r, model_cfg),
                     None if fused_scoring else loss_fn, ds, None,
                     logits_fn=logits_fn)
        hist = tr.run()
        syncs = max(h.host_syncs for h in hist)
        rec = {"bench": "strategy_host_syncs", "strategy": name,
               "engine": hist[-1].engine, "host_syncs_per_epoch": syncs,
               "guard_policy": guard_policy, "epochs": epochs,
               "fused_scoring": fused_scoring}
        assert rec["engine"] == "scan", rec
        assert syncs <= 1, rec
        records.append(rec)
        print("BENCH " + json.dumps(rec))
    return records


def rank_plan_overhead(iters: int = 5) -> list[dict]:
    """FORGET/DropTop rank-window plans: radix count-then-select vs the
    argsort they replaced.

    Times ``planops.topk_hide`` (now radix-routed) against the retained
    ``stable_rank_order < k`` oracle and ``planops.sort_high_mask`` against
    ``sort_high_mask_argsort``, asserting the masks BIT-IDENTICAL at every
    size before recording the speedup — the Table-1 selection-cost row.
    """
    import jax.numpy as jnp

    from repro.core import planops

    @jax.jit
    def topk_oracle(scores, k):
        return planops.stable_rank_order(scores) < k

    records = []
    for n in (100_000, 1_000_000):
        r = np.random.default_rng(0)
        scores = jnp.asarray(np.round(r.exponential(1, n), 3), jnp.float32)
        valid = jnp.asarray(r.random(n) < 0.9)
        k = jnp.int32(n // 3)

        mask_radix = np.asarray(planops.topk_hide(scores, k))
        mask_sort = np.asarray(topk_oracle(scores, k))
        assert (mask_radix == mask_sort).all(), f"topk_hide parity N={n}"
        t_radix = _bench(planops.topk_hide, scores, k, iters=iters)
        t_sort = _bench(topk_oracle, scores, k, iters=iters)
        rec = {"bench": "rank_plan_overhead", "plan": "forget_topk", "n": n,
               "radix_us": round(t_radix, 1), "argsort_us": round(t_sort, 1),
               "speedup_vs_argsort": round(t_sort / t_radix, 2),
               "masks_identical": True}
        records.append(rec)
        print(csv_row(f"selection/forget_topk_radix_N{n}", t_radix,
                      f"argsort={t_sort:.1f}us;x{t_sort / t_radix:.2f}"))
        print("BENCH " + json.dumps(rec))

        high_jit = jax.jit(planops.sort_high_mask)
        high_oracle = jax.jit(planops.sort_high_mask_argsort)
        m_radix = np.asarray(high_jit(scores, valid, 0.1))
        m_sort = np.asarray(high_oracle(scores, valid, 0.1))
        assert (m_radix == m_sort).all(), f"sort_high_mask parity N={n}"
        t_radix = _bench(high_jit, scores, valid, 0.1, iters=iters)
        t_sort = _bench(high_oracle, scores, valid, 0.1, iters=iters)
        rec = {"bench": "rank_plan_overhead", "plan": "droptop_high", "n": n,
               "radix_us": round(t_radix, 1), "argsort_us": round(t_sort, 1),
               "speedup_vs_argsort": round(t_sort / t_radix, 2),
               "masks_identical": True}
        records.append(rec)
        print(csv_row(f"selection/droptop_high_radix_N{n}", t_radix,
                      f"argsort={t_sort:.1f}us;x{t_sort / t_radix:.2f}"))
        print("BENCH " + json.dumps(rec))
    return records


def mesh_main() -> None:
    from repro.launch.mesh import data_parallel_ctx
    ctx = data_parallel_ctx(8)
    for n in (100_000, 1_000_000):
        for method in SELECTION_METHODS:
            if method == "histogram_pallas" and n > 100_000:
                continue  # interpret-mode kernels: bench the smaller N only
            plan_us = _plan_time_us(n, method, iters=3, ctx=ctx)
            note = ("global GSPMD argsort, O(N) gather" if method == "sort"
                    else "shard_map histogram, O(bins) psum")
            print(csv_row(f"selection_mesh/{method}_N{n}", plan_us, note))
            print("BENCH " + json.dumps({
                "bench": "selection_overhead_mesh", "devices": 8, "n": n,
                "method": method, "plan_us": round(plan_us, 1)}))
    sync = _epoch_sync_counts(ctx=ctx)
    assert sync["host_syncs_fused"] == 1, sync
    assert sync["host_syncs_legacy"] == sync["batches"] + 1, sync
    print("BENCH " + json.dumps(
        {"bench": "sample_state_host_syncs_mesh", **sync}))


def main() -> None:
    for n in (100_000, 1_000_000):
        s = _observed_state(n)
        times = {}
        for method in SELECTION_METHODS:
            if method == "histogram_pallas" and n > 100_000:
                continue  # interpret-mode kernels: bench the smaller N only
            times[method] = _bench(
                lambda st, m=method: select_hidden(st, 0.3, method=m), s)
        base = times["sort"]
        for method, t in times.items():
            note = ("method=argsort;O(NlogN)" if method == "sort" else
                    f"method={method};O(N);speedup={base / t:.2f}x")
            print(csv_row(f"selection/{method}_N{n}", t, note))
            plan_us = _plan_time_us(n, method, iters=3)
            print("BENCH " + json.dumps({
                "bench": "selection_overhead", "n": n, "method": method,
                "select_us": round(t, 1), "plan_us": round(plan_us, 1),
                "speedup_vs_sort": round(base / t, 2)}))

    rank_plan_overhead()
    sync = _epoch_sync_counts()
    assert sync["host_syncs_fused"] == 1, sync
    assert sync["host_syncs_legacy"] == sync["batches"] + 1, sync
    print("BENCH " + json.dumps({"bench": "sample_state_host_syncs", **sync}))
    strategy_sync_counts()
    strategy_sync_counts(fused_scoring=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mesh", action="store_true",
                    help="bench the mesh-sharded selection engine on an "
                         "8-device host-simulated ('data',) mesh")
    args = ap.parse_args()
    mesh_main() if args.mesh else main()
