"""End-to-end step throughput: host-loop vs scanned epoch engine.

The quantity KAKURENBO's wall-clock claim rests on is steps/second — hiding
samples only pays if the freed steps aren't eaten by per-step overhead
(host batch assembly, H2D copies, one dispatch per batch, a blocking
``float(loss)`` sync).  This benchmark times exactly the engine layer
(``Trainer.engine.run_epoch``: the batch loop alone — no eval, no step-D
refresh, plan time excluded) for both engines over a hidden-fraction sweep,
emitting one ``BENCH {json}`` line per (engine, fraction) cell:

  samples/sec, steps/sec, per-epoch host-sync count, and the scanned/host
  speedup per fraction.

On CPU at small batch sizes dispatch overhead dominates compute, which is
where the scanned engine's gather-based assembly + multi-step ``lax.scan``
dispatch shows up directly in steps/sec.  Recorded numbers live in
``results/BENCH_steps.json`` and ``docs/benchmarks.md``.

``--strategies all`` sweeps the whole strategy registry instead of the
hidden-fraction grid: one (strategy, engine) cell per registered name, so
the scan-vs-host speedup is recorded per strategy now that PlanOps makes
every strategy scan-capable.  With ``--out`` the records are APPENDED to an
existing BENCH file (``results/BENCH_steps.json``) rather than replacing it.

``--smoke`` runs a tiny CI configuration and asserts the contract rather
than the timing: the scanned engine actually engages — for *every*
registered strategy — emits BENCH lines, and a device-planned scanned epoch
costs O(1) SampleState/plan host syncs (1 = the plan materialisation)
instead of O(batches).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import (
    ForgetConfig, KakurenboConfig, LRSchedule, available_strategies,
)
from repro.data import SyntheticClassification
from repro.models import cnn
from repro.train import Trainer, TrainConfig

MODEL_CFG = cnn.CNNConfig(image_size=16, widths=(16, 32), hidden=64)


def _fns():
    import jax.numpy as jnp

    def init_params(rng):
        return cnn.init(rng, MODEL_CFG)

    def loss_fn(params, batch):
        logits = cnn.forward(params, MODEL_CFG, batch["images"])
        loss, pa, pc = cnn.per_sample_metrics(logits, batch["labels"])
        w = batch.get("weight")
        scalar = jnp.mean(loss * w) if w is not None else jnp.mean(loss)
        return scalar, (loss, pa, pc)

    return init_params, loss_fn


def build_trainer(engine: str, hidden_fraction: float, *, num_samples: int,
                  batch_size: int, epochs: int, scan_steps: int,
                  strategy: str | None = None,
                  guard_policy: str = "off") -> Trainer:
    # Without an explicit strategy: fraction 0 -> the baseline strategy
    # (nothing to hide, pure engine overhead comparison); otherwise
    # KAKURENBO at F_e = hidden_fraction with the O(N) histogram plan.
    # With one (--strategies all): the registered name, hiding machinery
    # configured the same way where applicable.
    strategy = strategy or ("baseline" if hidden_fraction == 0
                            else "kakurenbo")
    kc = KakurenboConfig(selection="histogram",
                         max_fraction=hidden_fraction or 0.3,
                         fraction_milestones=(0, 1, 2, 3))
    tc = TrainConfig(
        epochs=epochs, batch_size=batch_size, strategy=strategy,
        engine=engine, scan_steps=scan_steps, kakurenbo=kc,
        forget=ForgetConfig(fraction=0.3,
                            warmup_epochs=max(epochs // 2, 1)),
        lr=LRSchedule(0.05, "cosine", epochs, 1), seed=0,
        guard_policy=guard_policy)
    ds = SyntheticClassification(num_samples=num_samples, seed=0)
    init_params, loss_fn = _fns()
    return Trainer(tc, init_params, loss_fn, ds, None)


def bench_engine(engine: str, hidden_fraction: float, *,
                 num_samples: int = 4096, batch_size: int = 128,
                 epochs: int = 8, scan_steps: int = 8,
                 strategy: str | None = None,
                 guard_policy: str = "off") -> dict:
    """Train ``epochs`` epochs; report the *median* per-epoch batch-loop
    throughput over every epoch after the first.

    The scanned engine's block shapes are all pre-compiled via
    ``ScanEpochEngine.warmup()`` and epoch 0 warms the host path, so timed
    epochs are compile-free; the median additionally shields the record
    from container noise.  The result is steady-state dispatch throughput —
    the quantity the engines actually differ on.
    """
    tr = build_trainer(engine, hidden_fraction, num_samples=num_samples,
                       batch_size=batch_size, epochs=epochs,
                       scan_steps=scan_steps, strategy=strategy,
                       guard_policy=guard_policy)
    if hasattr(tr.engine, "warmup"):
        tr.engine.warmup()   # compile all block shapes before the clock
    rates = []
    host_syncs = []
    for epoch in range(epochs):
        indices, plan = tr._epoch_indices(epoch)
        lr = float(tr.cfg.lr(epoch)) * plan.lr_scale
        t0 = time.perf_counter()
        res = tr.engine.run_epoch(epoch, indices, plan, lr)
        dt = time.perf_counter() - t0
        if plan.needs_refresh:
            def fwd_fn(idx):
                return tr._eval_step(tr.params, tr.dataset.get(idx))
            tr.strategy.on_epoch_end(plan, fwd_fn, tr.cfg.batch_size)
        if epoch > 0:  # epoch 0 is compile + warmup
            rates.append(len(res.losses) / dt)
            host_syncs.append(plan.host_syncs + res.host_syncs)
    steps_per_s = float(np.median(rates))
    return {
        "bench": ("step_throughput_strategy" if strategy
                  else "step_throughput"),
        "strategy": tr.strategy.name,
        "engine": tr.engine.name,
        "hidden_fraction": None if strategy else hidden_fraction,
        "batch_size": batch_size,
        "num_samples": num_samples,
        "scan_steps": scan_steps if tr.engine.name == "scan" else None,
        "guard_policy": guard_policy,
        "steps_per_s": round(steps_per_s, 2),
        "samples_per_s": round(steps_per_s * batch_size, 1),
        "min_steps_per_s": round(float(np.min(rates)), 2),
        "host_syncs_per_epoch": max(host_syncs),
        "timed_epochs": epochs - 1,
    }


def _write(records: list[dict], out: str | None) -> None:
    """Append records to ``out`` (keeping earlier BENCH runs' records)."""
    if not out:
        return
    existing = []
    if os.path.exists(out):
        with open(out) as f:
            existing = json.load(f)
    with open(out, "w") as f:
        json.dump(existing + records, f, indent=1)
    print(f"wrote {len(records)} records to {out} "
          f"({len(existing)} pre-existing kept)")


def main(out: str | None) -> None:
    records = []
    for fraction in (0.0, 0.1, 0.3):
        cells = {}
        for engine in ("host", "scan"):
            rec = bench_engine(engine, fraction)
            cells[engine] = rec
            records.append(rec)
            print("BENCH " + json.dumps(rec))
        speedup = {
            "bench": "step_throughput_speedup",
            "hidden_fraction": fraction,
            "batch_size": cells["host"]["batch_size"],
            "scan_over_host":
                round(cells["scan"]["steps_per_s"]
                      / cells["host"]["steps_per_s"], 3),
        }
        records.append(speedup)
        print("BENCH " + json.dumps(speedup))
    _write(records, out)


def strategies_main(out: str | None) -> None:
    """scan-vs-host throughput for every registered strategy (PlanOps made
    the whole registry scan-capable, so the sweep is apples-to-apples)."""
    records = []
    for name in available_strategies():
        cells = {}
        for engine in ("host", "scan"):
            rec = bench_engine(engine, 0.0, strategy=name, num_samples=2048,
                               batch_size=128, epochs=5)
            cells[engine] = rec
            records.append(rec)
            print("BENCH " + json.dumps(rec))
        speedup = {
            "bench": "step_throughput_strategy_speedup",
            "strategy": name,
            "batch_size": cells["host"]["batch_size"],
            "scan_over_host":
                round(cells["scan"]["steps_per_s"]
                      / cells["host"]["steps_per_s"], 3),
        }
        records.append(speedup)
        print("BENCH " + json.dumps(speedup))
    _write(records, out)


def fused_scoring_main(out: str | None, *, batch_size: int = 1024,
                       num_classes: int = 8192, epochs: int = 5) -> None:
    """Fused one-pass scoring vs the model's separate jnp passes.

    A wide-head classifier (``num_classes`` logits per sample, small conv
    front-end — the LM-like regime where the (B, V) logits tensor dominates
    the step) at batch >= 1024 makes the per-sample (loss, PA, PC) scoring
    a measurable share: the jnp path reduces the logits ~4x (logsumexp,
    gather, argmax, max) and re-derives the softmax in autodiff, while
    ``TrainConfig.fused_scoring`` does one streaming pass with an analytic
    backward (isolated, the scoring+grad alone is >2x faster at these
    shapes).  Same model, same data, same scanned engine — the delta is the
    scoring alone.  Appended to ``results/BENCH_steps.json``.
    """
    import jax.numpy as jnp

    from repro.core import LRSchedule

    model_cfg = cnn.CNNConfig(image_size=8, widths=(8,), hidden=32,
                              num_classes=num_classes)

    def init_params(rng):
        return cnn.init(rng, model_cfg)

    def logits_fn(params, batch):
        return cnn.forward(params, model_cfg, batch["images"])

    def loss_fn(params, batch):
        logits = logits_fn(params, batch)
        loss, pa, pc = cnn.per_sample_metrics(logits, batch["labels"])
        w = batch.get("weight")
        scalar = jnp.mean(loss * w) if w is not None else jnp.mean(loss)
        return scalar, (loss, pa, pc)

    num_samples = 4 * batch_size
    ds = SyntheticClassification(num_samples=num_samples, image_size=8,
                                 num_classes=num_classes, seed=0)
    records = []
    cells = {}
    for fused in (False, True):
        tc = TrainConfig(
            epochs=epochs, batch_size=batch_size, strategy="kakurenbo",
            engine="scan", scan_steps=2,
            kakurenbo=KakurenboConfig(selection="histogram", max_fraction=0.3,
                                      fraction_milestones=(0, 1, 2, 3)),
            lr=LRSchedule(0.05, "cosine", epochs, 1), seed=0,
            fused_scoring=fused)
        tr = Trainer(tc, init_params, None if fused else loss_fn, ds, None,
                     logits_fn=logits_fn)
        if hasattr(tr.engine, "warmup"):
            tr.engine.warmup()
        rates = []
        for epoch in range(epochs):
            indices, plan = tr._epoch_indices(epoch)
            lr = float(tr.cfg.lr(epoch)) * plan.lr_scale
            t0 = time.perf_counter()
            res = tr.engine.run_epoch(epoch, indices, plan, lr)
            dt = time.perf_counter() - t0
            if epoch > 0:
                rates.append(len(res.losses) / dt)
        rec = {
            "bench": "step_throughput_fused_scoring",
            "fused_scoring": fused, "engine": tr.engine.name,
            "batch_size": batch_size, "num_classes": num_classes,
            "num_samples": num_samples,
            "samples_per_s": round(float(np.median(rates)) * batch_size, 1),
            "timed_epochs": epochs - 1,
        }
        cells[fused] = rec
        records.append(rec)
        print("BENCH " + json.dumps(rec))
    speedup = {
        "bench": "step_throughput_fused_scoring_speedup",
        "batch_size": batch_size, "num_classes": num_classes,
        "fused_over_jnp": round(cells[True]["samples_per_s"]
                                / cells[False]["samples_per_s"], 3),
    }
    records.append(speedup)
    print("BENCH " + json.dumps(speedup))
    _write(records, out)


def guard_main(out: str | None, max_overhead_pct: float = 3.0) -> None:
    """Numeric-guard overhead: the same scanned kakurenbo run with
    ``guard_policy`` off vs ``skip_update``.

    The guard's in-step work is a handful of ``isfinite`` reductions and
    pytree selects per step — O(params) elementwise next to the conv
    grads — and its counters ride the epoch-end fetch, so the contract is
    *under ``max_overhead_pct`` percent* steady-state overhead at the
    reference batch size (asserted here, recorded in the BENCH file).
    """
    records = []
    cells = {}
    for policy in ("off", "skip_update"):
        rec = bench_engine("scan", 0.3, guard_policy=policy)
        cells[policy] = rec
        records.append(rec)
        print("BENCH " + json.dumps(rec))
    overhead_pct = round(
        100.0 * (cells["off"]["steps_per_s"]
                 / cells["skip_update"]["steps_per_s"] - 1.0), 2)
    rec = {
        "bench": "guard_overhead",
        "strategy": cells["off"]["strategy"],
        "engine": "scan",
        "batch_size": cells["off"]["batch_size"],
        "steps_per_s_off": cells["off"]["steps_per_s"],
        "steps_per_s_guarded": cells["skip_update"]["steps_per_s"],
        "overhead_pct": overhead_pct,
        "max_overhead_pct": max_overhead_pct,
    }
    records.append(rec)
    print("BENCH " + json.dumps(rec))
    assert overhead_pct < max_overhead_pct, rec
    _write(records, out)


def smoke() -> None:
    """CI contract check (timing-free): the scanned engine engages — for
    every registered strategy — emits BENCH records, and device-planned
    scanned epochs cost O(1) host syncs."""
    bench = []
    for engine in ("host", "scan"):
        rec = bench_engine(engine, 0.3, num_samples=512, batch_size=64,
                           epochs=2, scan_steps=4)
        bench.append(rec)
        print("BENCH " + json.dumps(rec))
    host, scan = bench
    assert scan["engine"] == "scan", scan       # auto didn't silently fall back
    assert host["engine"] == "host", host
    # no per-step host-sync regression: the scanned epoch's SampleState
    # crosses the host boundary once (the plan), never per batch
    assert scan["host_syncs_per_epoch"] == 1, scan
    assert scan["steps_per_s"] > 0, scan        # the BENCH record is real
    # the PlanOps bar: every registered strategy is scan-capable under
    # engine="auto" and keeps the 1-host-sync/epoch plan contract
    for name in available_strategies():
        rec = bench_engine("auto", 0.0, strategy=name, num_samples=256,
                           batch_size=64, epochs=2, scan_steps=4)
        bench.append(rec)
        print("BENCH " + json.dumps(rec))
        assert rec["engine"] == "scan", rec
        assert rec["host_syncs_per_epoch"] <= 1, rec
        assert rec["steps_per_s"] > 0, rec
    print(f"SMOKE_OK {len(bench)} BENCH lines")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run asserting the engine/host-sync "
                         "contract instead of recording timings")
    ap.add_argument("--strategies", choices=("sweep", "all"), default="sweep",
                    help="'all' benches every registered strategy "
                         "(scan vs host) instead of the hidden-fraction "
                         "sweep")
    ap.add_argument("--guard", action="store_true",
                    help="bench guard_policy off vs skip_update and assert "
                         "the guard's steady-state overhead stays under 3%%")
    ap.add_argument("--fused-scoring", action="store_true",
                    help="bench TrainConfig.fused_scoring (one-pass fused "
                         "loss/PA/PC) vs the jnp scoring path on a "
                         "wide-head model at batch>=1024")
    ap.add_argument("--out", default=None,
                    help="append BENCH records to this JSON file "
                         "(e.g. results/BENCH_steps.json)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    elif args.guard:
        guard_main(args.out)
    elif args.fused_scoring:
        fused_scoring_main(args.out)
    elif args.strategies == "all":
        strategies_main(args.out)
    else:
        main(args.out)
