"""Kernel microbenchmarks + roofline gating — the per-PR kernel record.

One harness times every Pallas kernel against its jnp oracle and divides by
the analytic per-call HBM floor (``launch/roofline_model.kernel_hbm_bytes``)
to get an achieved-bandwidth column, compared against the machine's
*measured* stream bandwidth (a big ``jnp.copy``) as the roofline ceiling.
Two record kinds land in ``results/BENCH_kernels.json``:

  {"bench": "kernel_micro",    "kernel", "shape", "us_kernel", "us_oracle",
   "us_kernel_median", "hbm_bytes", "gbps_kernel", "backend", "iters"}
  {"bench": "kernel_roofline", "kernel", "shape", "gbps_kernel",
   "gbps_stream", "roofline_fraction", "backend"}

Timing discipline: every callable is warmed up (compile + first dispatch
excluded), then timed per-iteration; ``us_kernel`` is the BEST of k (the
dispatch floor, the stable cross-PR comparator) and the median rides along
as the noise check.  The backend column comes from the single probe
(``kernels/backend.py``): "interpret" on this CPU container — NOT
TPU-representative, tracked for regressions and exercised for correctness —
"pallas" on real hardware, with ``REPRO_PALLAS_INTERPRET`` overriding.

``--smoke`` is the CI gate (timing-free, tiny shapes): kernel-vs-oracle
parity for every kernel, radix rank-select masks bit-identical to the
argsort oracle, the fused-scoring strategy sweep keeping 1 host sync/epoch,
and roofline-record sanity.  Any mismatch fails the step.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import planops
from repro.kernels import backend, ops, ref
from repro.launch.roofline_model import kernel_hbm_bytes

#: Bytes moved by the stream probe (read + write counted below).
STREAM_MB = 64


def _bench(fn, *args, iters: int = 5, warmup: int = 2):
    """(best_us, median_us) over ``iters`` timed calls, compile excluded."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return min(times), float(np.median(times))


def stream_bandwidth_gbps(iters: int = 5) -> float:
    """Measured copy bandwidth (GB/s) — the machine's roofline ceiling.

    A device-to-device copy of a STREAM_MB f32 array; bytes counted as
    read + write.  This is the same ceiling for every kernel row, so
    ``roofline_fraction`` is comparable within one BENCH file even though
    the absolute number is container-dependent.
    """
    x = jnp.zeros((STREAM_MB * 1024 * 1024 // 4,), jnp.float32)
    copy = jax.jit(lambda a: a + 0.0)
    best, _ = _bench(copy, x, iters=iters)
    return 2 * x.size * 4 / (best * 1e-6) / 1e9


def _cases(small: bool):
    """(kernel, shape, fn, oracle_fn, args) rows for the sweep.

    ``small`` shrinks every shape to smoke size (seconds, not minutes, under
    the interpreter) — parity is shape-independent because the kernels are
    exercised on non-multiple-of-block sizes elsewhere (tests/).
    """
    r = np.random.default_rng(0)
    rows = []

    b, s, hq, hkv, d = (1, 128, 2, 1, 32) if small else (2, 512, 4, 2, 64)
    q = jnp.asarray(r.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(r.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(r.normal(size=(b, s, hkv, d)), jnp.float32)
    rows.append(("flash_attention",
                 {"b": b, "s": s, "hq": hq, "hkv": hkv, "d": d},
                 ops.flash_attention, ref.flash_attention_ref, (q, k, v)))

    b, s, nh, p, n = (1, 128, 2, 16, 8) if small else (2, 512, 4, 32, 16)
    x = jnp.asarray(r.normal(size=(b, s, nh, p)), jnp.float32)
    dt = jnp.asarray(r.normal(size=(b, s, nh)), jnp.float32)
    a_log = jnp.asarray(r.normal(size=(nh,)), jnp.float32)
    bb = jnp.asarray(r.normal(size=(b, s, n)), jnp.float32)
    cc = jnp.asarray(r.normal(size=(b, s, n)), jnp.float32)
    dsk = jnp.asarray(r.normal(size=(nh,)), jnp.float32)
    chunk = 64 if small else 128
    rows.append(("ssd_scan", {"b": b, "s": s, "nh": nh, "p": p, "n": n},
                 lambda *a: ops.ssd_scan(*a, chunk=chunk),
                 lambda *a: ref.ssd_scan_ref(*a, chunk=chunk),
                 (x, dt, a_log, bb, cc, dsk)))

    t, vv = (256, 512) if small else (512, 4096)
    lg = jnp.asarray(r.normal(size=(t, vv)), jnp.float32)
    lab = jnp.asarray(r.integers(0, vv, t), jnp.int32)
    rows.append(("loss_confidence", {"t": t, "v": vv},
                 ops.loss_confidence, ref.loss_confidence_ref, (lg, lab)))
    # The hot-path scoring (both dispatch modes are XLA-compiled; this row
    # is what the train step actually pays, unlike the interpreted kernel).
    rows.append(("fused_scoring", {"t": t, "v": vv},
                 jax.jit(lambda a, b_: ops.fused_loss_metrics(
                     a, b_, scoring="reference")),
                 ref.loss_confidence_ref, (lg, lab)))

    n = 8192 if small else 65536
    loss = jnp.asarray(r.exponential(1, n), jnp.float32)
    valid = jnp.ones(n, bool)
    lo, hi = jnp.float32(0), jnp.float32(8)
    rows.append(("loss_histogram", {"n": n},
                 lambda l, m: ops.loss_histogram(l, m, lo, hi),
                 lambda l, m: ref.histogram_ref(l, m, lo, hi, 512),
                 (loss, valid)))
    rows.append(("loss_minmax", {"n": n},
                 ops.loss_minmax, ref.minmax_ref, (loss, valid)))

    # Radix count-then-select vs the stable argsort it replaced in the
    # FORGET/DropTop plans (jnp radix under the interpreter, kernels on TPU).
    scores = jnp.asarray(r.exponential(1, n), jnp.float32)
    kk = jnp.int32(n // 3)
    rows.append(("rank_select", {"n": n},
                 lambda sc: ops.rank_select(sc, kk),
                 jax.jit(lambda sc: planops.stable_rank_order(sc) < kk),
                 (scores,)))
    return rows


def _records(small: bool = False, iters: int = 5):
    gbps_stream = stream_bandwidth_gbps()
    bname = backend.backend_name()
    records = []
    for kernel, shape, fn, oracle, args in _cases(small):
        best, med = _bench(fn, *args, iters=iters)
        obest, _ = _bench(oracle, *args, iters=iters)
        hbm = kernel_hbm_bytes(kernel, **shape)
        gbps = hbm / (best * 1e-6) / 1e9
        records.append({
            "bench": "kernel_micro", "kernel": kernel, "shape": shape,
            "us_kernel": round(best, 1), "us_oracle": round(obest, 1),
            "us_kernel_median": round(med, 1), "hbm_bytes": hbm,
            "gbps_kernel": round(gbps, 4), "backend": bname, "iters": iters,
        })
        records.append({
            "bench": "kernel_roofline", "kernel": kernel, "shape": shape,
            "gbps_kernel": round(gbps, 4),
            "gbps_stream": round(gbps_stream, 2),
            "roofline_fraction": round(gbps / gbps_stream, 4),
            "backend": bname,
        })
    return records


def _write(records: list[dict], out: str | None) -> None:
    """REPLACE ``out`` with this run's records: the file is the per-PR
    kernel snapshot (append would mix machines/backends and break the
    regression comparison)."""
    if not out:
        return
    with open(out, "w") as f:
        json.dump(records, f, indent=1)
    print(f"wrote {len(records)} records to {out}")


def main(out: str | None) -> None:
    records = _records()
    for rec in records:
        print("BENCH " + json.dumps(rec))
    _write(records, out)


# ---------------------------------------------------------------------------
# --smoke: the CI gate
# ---------------------------------------------------------------------------


def _assert_close(name, got, want, tol):
    got, want = np.asarray(got, np.float64), np.asarray(want, np.float64)
    err = float(np.max(np.abs(got - want))) if got.size else 0.0
    assert err <= tol, f"{name}: kernel/oracle mismatch max|Δ|={err} > {tol}"


def smoke() -> None:
    """Parity + contract gate: fails CI on any kernel/oracle divergence."""
    # 1. kernel vs oracle parity on every benched kernel (small shapes).
    for kernel, shape, fn, oracle, args in _cases(small=True):
        got, want = fn(*args), oracle(*args)
        got = got if isinstance(got, tuple) else (got,)
        want = want if isinstance(want, tuple) else (want,)
        for i, (g, w) in enumerate(zip(got, want)):
            if g.dtype == bool or w.dtype == bool or kernel == "rank_select":
                assert (np.asarray(g) == np.asarray(w)).all(), (
                    f"{kernel}[{i}]: boolean output differs from oracle")
            else:
                _assert_close(f"{kernel}[{i}]", g, w, 2e-3)
        print(f"parity OK: {kernel} {shape}")

    # 2. radix rank-select bit-identity vs the stable argsort oracle, both
    # tails, ties included — the FORGET/DropTop plan contract.
    r = np.random.default_rng(1)
    scores = jnp.asarray(np.round(r.exponential(1, 4097), 2), jnp.float32)
    for k in (0, 1, 1365, 4096, 4097):
        rank = planops.stable_rank_order(scores)
        low = ops.rank_select(scores, jnp.int32(k))
        assert (np.asarray(low) == np.asarray(rank < k)).all(), (k, "low")
        high = ops.rank_select(scores, jnp.int32(k), high=True)
        n = scores.shape[0]
        assert (np.asarray(high) == np.asarray(rank >= n - k)).all(), (
            k, "high")
    print("parity OK: rank_select tie/tail sweep")

    # 3. fused scoring differentiates like the oracle loss.
    lg = jnp.asarray(r.normal(size=(64, 257)), jnp.float32)
    lab = jnp.asarray(r.integers(0, 257, 64), jnp.int32)
    g_f = jax.grad(lambda a: ops.fused_loss_metrics(a, lab)[0].mean())(lg)
    g_o = jax.grad(lambda a: ref.loss_confidence_ref(a, lab)[0].mean())(lg)
    _assert_close("fused_scoring_grad", g_f, g_o, 1e-5)
    print("parity OK: fused_scoring vjp")

    # 4. the train-loop contract: every strategy stays at 1 host sync/epoch
    # with the fused scoring active (the scatter feeds off the fused triple).
    from benchmarks.selection_overhead import strategy_sync_counts
    strategy_sync_counts(num_samples=256, batch=64, epochs=2,
                         fused_scoring=True)

    # 5. roofline rows are sane on this backend.
    recs = _records(small=True, iters=2)
    for rec in recs:
        if rec["bench"] != "kernel_roofline":
            continue
        assert rec["gbps_stream"] > 0 and rec["gbps_kernel"] > 0, rec
        assert 0 < rec["roofline_fraction"], rec
        print("BENCH " + json.dumps(rec))
    print(f"SMOKE_OK backend={backend.backend_name()}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: kernel/oracle parity, rank-select "
                         "bit-identity, fused-scoring sync contract, "
                         "roofline sanity — no timings recorded")
    ap.add_argument("--out", default=None,
                    help="write this run's records to a JSON file "
                         "(e.g. results/BENCH_kernels.json; replaced, not "
                         "appended — the file is the per-PR snapshot)")
    ap.add_argument("--iters", type=int, default=5)
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        main(args.out)
