"""Kernel microbenchmarks (interpret-mode timings are NOT TPU-representative;
included to exercise the kernel paths end-to-end and track regressions)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from benchmarks.common import csv_row


def _bench(fn, *args, iters=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main() -> None:
    r = np.random.default_rng(0)
    lg = jnp.asarray(r.normal(size=(512, 4096)), jnp.float32)
    lab = jnp.asarray(r.integers(0, 4096, 512), jnp.int32)
    t = _bench(ops.loss_confidence, lg, lab)
    print(csv_row("kernel/loss_confidence_512x4096", t, "interpret=True"))
    loss = jnp.asarray(r.exponential(1, 65536), jnp.float32)
    valid = jnp.ones(65536, bool)
    t = _bench(lambda l, v: ops.loss_histogram(l, v, jnp.float32(0),
                                               jnp.float32(8)), loss, valid)
    print(csv_row("kernel/histogram_64k", t, "bins=512;interpret=True"))


if __name__ == "__main__":
    main()
