"""§Roofline reader: aggregates the dry-run JSONs into the per-cell table."""
import glob
import json
import os

from benchmarks.common import csv_row

DEFAULT_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun_roofline")


def rows(directory: str = DEFAULT_DIR):
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        d = json.load(open(path))
        name = f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}"
        if d["status"] != "ok":
            yield name, 0.0, f"status={d['status']}"
            continue
        r = d["roofline"]
        yield (name, r["step_time_s"] * 1e6,
               f"bottleneck={r['bottleneck']};"
               f"t_comp={r['t_compute_s']:.2e};t_mem={r['t_memory_s']:.2e};"
               f"t_coll={r['t_collective_s']:.2e};"
               f"useful_ratio={d.get('useful_flops_ratio') or 0:.3f}")


def main() -> None:
    if not os.path.isdir(DEFAULT_DIR):
        print(csv_row("roofline/missing", 0.0,
                      f"run `python -m repro.launch.dryrun` first ({DEFAULT_DIR})"))
        return
    for name, us, derived in rows():
        print(csv_row(name, us, derived))


if __name__ == "__main__":
    main()
