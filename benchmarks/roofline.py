"""§Roofline reader: dry-run cells + the per-PR kernel roofline records.

Aggregates two sources into one CSV view:

- the launch dry-run JSONs (``results/dryrun_roofline``, produced by
  ``python -m repro.launch.dryrun``): per-(arch, shape, mesh) step-time
  roofline cells;
- the kernel records of ``results/BENCH_kernels.json`` (produced by
  ``python -m benchmarks.kernel_micro --out ...``): per-kernel achieved
  bandwidth vs the machine's measured stream ceiling
  (``kernel_roofline`` rows of the shared schema).
"""
import glob
import json
import os

from benchmarks.common import csv_row

DEFAULT_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun_roofline")
KERNEL_BENCH = os.environ.get("KERNEL_BENCH", "results/BENCH_kernels.json")


def rows(directory: str = DEFAULT_DIR):
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        d = json.load(open(path))
        name = f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}"
        if d["status"] != "ok":
            yield name, 0.0, f"status={d['status']}"
            continue
        r = d["roofline"]
        yield (name, r["step_time_s"] * 1e6,
               f"bottleneck={r['bottleneck']};"
               f"t_comp={r['t_compute_s']:.2e};t_mem={r['t_memory_s']:.2e};"
               f"t_coll={r['t_collective_s']:.2e};"
               f"useful_ratio={d.get('useful_flops_ratio') or 0:.3f}")


def kernel_rows(path: str = KERNEL_BENCH):
    """CSV rows from the kernel bench snapshot (empty if not yet recorded)."""
    if not os.path.exists(path):
        return
    for rec in json.load(open(path)):
        if rec.get("bench") != "kernel_roofline":
            continue
        shape = "x".join(str(v) for v in rec["shape"].values())
        yield (f"roofline/kernel/{rec['kernel']}/{shape}",
               rec["gbps_kernel"] * 1e3,   # MB/ms, keeps the us column sane
               f"gbps={rec['gbps_kernel']};stream={rec['gbps_stream']};"
               f"fraction={rec['roofline_fraction']};"
               f"backend={rec['backend']}")


def main() -> None:
    printed = False
    if os.path.isdir(DEFAULT_DIR):
        for name, us, derived in rows():
            print(csv_row(name, us, derived))
            printed = True
    for name, us, derived in kernel_rows():
        print(csv_row(name, us, derived))
        printed = True
    if not printed:
        print(csv_row(
            "roofline/missing", 0.0,
            f"run `python -m repro.launch.dryrun` ({DEFAULT_DIR}) and/or "
            f"`python -m benchmarks.kernel_micro --out {KERNEL_BENCH}`"))


if __name__ == "__main__":
    main()
