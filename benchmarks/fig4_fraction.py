"""Paper Fig. 4: evolution of the hiding fraction and per-epoch speedup."""
from benchmarks.common import csv_row, run_strategy


def main() -> None:
    base = run_strategy("baseline")
    kk = run_strategy("kakurenbo")
    base_epoch = [h.wall_time for h in base["history"]]
    for h, bt in zip(kk["history"], base_epoch):
        speedup = bt / h.wall_time if h.wall_time else float("nan")
        print(csv_row(f"fig4/epoch{h.epoch}", h.wall_time * 1e6,
                      f"hidden_fraction={h.hidden_fraction:.3f};"
                      f"epoch_speedup={speedup:.3f}"))


if __name__ == "__main__":
    main()
