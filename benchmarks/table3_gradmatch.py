"""Paper Table 3: Grad-Match vs KAKURENBO, single-worker setting."""
from benchmarks.common import EPOCHS, csv_row, run_strategy


def main() -> None:
    base = run_strategy("baseline")
    gm = run_strategy("gradmatch")
    kk = run_strategy("kakurenbo")
    for name, res in (("table3/baseline", base), ("table3/gradmatch-0.3", gm),
                      ("table3/kakurenbo-0.3", kk)):
        print(csv_row(name, res["wall_s"] / EPOCHS * 1e6,
                      f"best_acc={res['best_acc']:.4f};"
                      f"time_vs_base={res['wall_s'] / base['wall_s']:.3f}"))


if __name__ == "__main__":
    main()
