from repro.train.trainer import Trainer, TrainConfig, EpochStats  # noqa: F401
