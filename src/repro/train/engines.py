"""Epoch engines: how a planned epoch's batches become train steps.

The trainer owns *what* trains (the strategy's ``EpochPlan``) and the step
math (``Trainer._step_core``: loss/grads, optional compression, optimizer
update, optional fused observe scatter — single-device or mesh-sharded).
An epoch engine owns *how* the plan is dispatched:

- ``HostLoopEngine`` — the classic loop: one jitted step per batch, batches
  assembled on the host by the ``Pipeline`` and shipped host→device each
  step.  The only engine that can run per-batch host hooks (host
  ``observe()`` when the fused scatter is off), so it is also the
  legacy-parity reference.  Per-step loss scalars are collected as device
  arrays and converted to floats once at epoch end — the loop never blocks
  on a step.

- ``ScanEpochEngine`` — the device-resident epoch: the full dataset is
  placed in device memory once (``Trainer.device_data``), every epoch's
  batch layout is shipped as one ``(num_steps, B)`` index-plan array
  (row-sharded over the data axes under a mesh), and batches are assembled
  *inside* the jitted step by gathering rows from the plan.
  ``TrainConfig.scan_steps`` consecutive steps are rolled into a single
  ``jax.lax.scan`` block per dispatch, with the ``TrainCarry`` (params,
  optimizer state, EF residual, SampleState) threaded through and per-step
  loss scalars coming back as the scan's stacked outputs — fetched with one
  ``device_get`` per epoch.  Per-sample ``batch_weights`` are pre-gathered
  into the plan (they are plan-time lookups by protocol contract), so a
  scanned epoch does zero per-batch host work.

The scan block uses ``unroll=True``: the K step bodies are inlined into one
XLA computation instead of a while loop.  That is what makes the scanned
engine *bit-identical* to the host loop — XLA compiles a rolled loop body
with different layouts/fusions than a standalone step (measurably different
conv-grad reductions), while the unrolled block reproduces the per-step
compilation exactly.  One dispatch still covers K batches, which is where
the wall-clock win comes from (``benchmarks/step_throughput.py``).

Engine choice (``Trainer._make_engine``) is per strategy capability:
``SampleStrategy.supports_scan`` strategies — all 8 registered ones — run
scanned by default (``TrainConfig.engine="auto"``, ``device_data=True``);
only the legacy ``fused_observe=False`` parity path (and host-planned
external strategies without a fused observe) keep the host loop.
Loss-dependent selection (Selective-Backprop) is the in-step
``fused_select`` hook inside ``Trainer._step_core``, so it runs identically
under either engine; its surviving-sample count comes back as a per-step
device scalar next to the loss, fetched once per epoch.
Both engines honour the same crash contract: the latest live train state is
always handed back (the ``finally`` blocks), so checkpoint-on-fault works
mid-epoch — at batch granularity in the host loop, at scan-block
granularity in the scanned engine.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.state import TrainCarry
from repro.core.strategy import SampleStrategy
from repro.data.pipeline import epoch_index_plan


@dataclasses.dataclass
class EpochRunResult:
    """What an engine hands back to ``Trainer.run_epoch``."""

    losses: np.ndarray        # (num_steps,) f64 per-step loss scalars
    fwd_samples: int
    bwd_samples: int
    host_syncs: int           # SampleState round trips spent in the loop
    # Numeric guard counters (train/guard.py) — *cumulative* run totals as
    # fetched from the device GuardState in the same epoch-end device_get
    # that materialises the losses (so guarding adds no host syncs); all 0
    # with the guard off.  The trainer diffs totals into per-epoch stats.
    nonfinite_steps: int = 0
    quarantined: int = 0
    guard_consecutive: int = 0


def _all_live(tree) -> bool:
    """True when no leaf is a donated-and-consumed (deleted) jax array.

    Crash-handback guard: a failure *between* dispatches leaves the carry
    fully live, but a failure *inside* a dispatch (device OOM, runtime
    error, interrupt) happens after donation — then neither the old carry
    nor the partial output is usable, and handing deleted buffers to the
    trainer would only turn the later checkpoint-on-fault into a confusing
    'Array has been deleted' error masking the original fault.
    """
    return not any(getattr(leaf, "is_deleted", lambda: False)()
                   for leaf in jax.tree.leaves(tree))


class HostLoopEngine:
    """Per-batch jitted dispatch with host-side batch assembly."""

    name = "host"

    def __init__(self, trainer):
        self.tr = trainer

    def run_epoch(self, epoch: int, indices: np.ndarray, plan,
                  lr: float) -> EpochRunResult:
        tr = self.tr
        fwd = 0
        losses, bwds = [], []
        # Fused paths: thread the strategy's device state through the jitted
        # step for the whole epoch; hand it back only at the epoch boundary.
        fuse = tr._fuse
        dev_state = (tr.strategy.get_device_state() if tr._thread_state
                     else None)
        gstate = tr.guard_state
        # Strategies that don't override observe() (e.g. baseline) keep no
        # per-sample state, so their no-op observe is not a host round trip.
        observes = type(tr.strategy).observe is not SampleStrategy.observe
        loop_syncs = 0
        host_quarantined = 0
        epoch_dev = jnp.int32(epoch)
        try:
            for idx, batch in tr.pipeline.batches(indices):
                fwd += len(idx)
                weight = tr.strategy.batch_weights(idx)
                b = dict(batch)
                if weight is not None:
                    b["weight"] = jnp.asarray(weight, jnp.float32)
                (tr.params, tr.opt_state, tr.ef_state, dev_state, gstate,
                 scalar, bwd, metrics) = tr._train_step(
                    tr.params, tr.opt_state, tr.ef_state, dev_state, gstate,
                    b, jnp.asarray(idx), epoch_dev, lr)
                # Device scalars only — converted to floats once at epoch
                # end, so the loop never blocks on a step's completion.  The
                # step reports its own backward count (fused-select
                # strategies train a loss-dependent subset of the batch).
                losses.append(scalar)
                bwds.append(bwd)
                if fuse is None:
                    lv, pa, pc = metrics
                    if gstate is not None and observes:
                        # Legacy host-observe path under the guard: filter
                        # the non-finite observations out before the
                        # strategy scatters them.  This path already syncs
                        # every batch, so the host-side mask is free.
                        lv = np.asarray(lv)
                        valid = np.isfinite(lv) & np.isfinite(np.asarray(pc))
                        if not valid.all():
                            host_quarantined += int((~valid).sum())
                            idx = np.asarray(idx)[valid]
                            lv, pa, pc = (lv[valid], np.asarray(pa)[valid],
                                          np.asarray(pc)[valid])
                    if len(np.asarray(idx)):
                        tr.strategy.observe(idx, lv, pa, pc, epoch)
                    loop_syncs += int(observes)
        finally:
            # The train step donates dev_state, so mid-epoch the strategy's
            # own reference may point at deleted buffers — always hand back
            # the latest live state, even on a crash (between dispatches;
            # see _all_live for the inside-a-dispatch case), so
            # checkpoint-on-fault (save_checkpoint -> strategy.state_dict)
            # stays valid.  The guard counters ride the same contract.
            if tr._thread_state and _all_live(dev_state):
                tr.strategy.set_device_state(dev_state)
            if gstate is not None and _all_live(gstate):
                tr.guard_state = gstate
            # Host-path quarantines join the cumulative totals the trainer
            # diffs (the device counters only see fused observations).
            tr._guard_host_q += host_quarantined
        nf = qr = consec = 0
        if losses:
            # The epoch's single loss/work materialisation (guard counters
            # included — no extra round trip).
            ls, bw, g = jax.device_get((losses, bwds, gstate))
            ls = np.asarray(ls, np.float64)
            bwd_total = int(np.sum(np.asarray(bw, np.int64)))
            if g is not None:
                nf, qr, consec = (int(g.nonfinite_steps), int(g.quarantined),
                                  int(g.consecutive))
        else:
            ls, bwd_total = np.zeros(0), 0
        return EpochRunResult(losses=ls, fwd_samples=fwd,
                              bwd_samples=bwd_total, host_syncs=loop_syncs,
                              nonfinite_steps=nf,
                              quarantined=qr + tr._guard_host_q,
                              guard_consecutive=consec)


def scan_block_sizes(num_steps: int, scan_steps: int) -> list[int]:
    """Partition an epoch's steps into scan-block lengths.

    As many full ``scan_steps`` blocks as fit, then the remainder as
    descending powers of two.  Any partition is bit-identical (blocks are
    unrolled, so splitting changes dispatch boundaries, not math); the
    point of the binary remainder is compile-cache stability: strategies
    like KAKURENBO change the visible count — and with it the remainder —
    every epoch, and naively compiling one block per distinct remainder
    length re-traces every epoch.  This way the engine only ever compiles
    block lengths from {scan_steps} ∪ {1, 2, 4, ...} — O(log scan_steps)
    shapes for the whole run.
    """
    sizes = [scan_steps] * (num_steps // scan_steps)
    rem = num_steps % scan_steps
    p = 1 << (scan_steps.bit_length())
    while rem:
        if rem >= p:
            sizes.append(p)
            rem -= p
        else:
            p >>= 1
    return sizes


class ScanEpochEngine:
    """Gather-based batch assembly + multi-step ``lax.scan`` dispatch."""

    name = "scan"

    def __init__(self, trainer):
        self.tr = trainer
        self.scan_steps = max(int(trainer.cfg.scan_steps), 1)
        self._block = None   # built lazily: see _build_block

    def _build_block(self):
        """Close the jitted scan block over the device-resident dataset.

        Deferred to the first ``run_epoch``/``warmup`` call so that merely
        constructing a Trainer (to restore a checkpoint, to evaluate, in a
        config-validation test) never pays dataset materialisation +
        device placement.
        """
        trainer = self.tr
        data = trainer.device_data()
        ctx = trainer.ctx
        step_core = trainer._step_core

        def block(carry, xs, epoch, lr):
            def body(c, x):
                batch = {k: jnp.take(v, x["idx"], axis=0)
                         for k, v in data.items()}
                if ctx.mesh is not None:
                    batch = ctx.constrain_rows(batch)
                if "w" in x:
                    batch["weight"] = x["w"]
                (params, opt_state, ef, sstate, gstate, scalar, bwd,
                 _) = step_core(
                    c.params, c.opt_state, c.ef, c.sstate, c.gstate, batch,
                    x["idx"], epoch, lr)
                return (TrainCarry(params, opt_state, ef, sstate, gstate),
                        (scalar, bwd))
            # unroll=True: the K bodies are inlined, reproducing the
            # standalone per-step compilation bit for bit (a rolled while
            # loop compiles the conv grads with different layouts); one
            # dispatch still covers the whole block.  A length-1 block
            # (scan_steps=1, or a remainder block when num_steps % K == 1)
            # is inlined by hand: XLA canonicalises a 1-trip scan through a
            # different graph whose conv grads are NOT bit-identical to the
            # standalone step.  Block length is static at trace time, so
            # this is a plain python branch.
            if jax.tree.leaves(xs)[0].shape[0] == 1:
                carry, out = body(carry, jax.tree.map(lambda a: a[0], xs))
                return carry, jax.tree.map(lambda a: a[None], out)
            return jax.lax.scan(body, carry, xs, unroll=True)

        self._block = jax.jit(block, donate_argnums=(0,))

    def warmup(self) -> int:
        """Compile every scan-block shape this engine can ever dispatch.

        Runs one dummy block per shape ({scan_steps} plus the power-of-2
        remainder lengths, see ``scan_block_sizes``) on a *cloned* carry —
        the real train state is untouched — so the jit cache is fully
        populated before the first timed/production epoch instead of paying
        a compile whenever a strategy's moving visible count first produces
        a new remainder length.  Returns the number of block shapes warmed.
        """
        if self._block is None:
            self._build_block()
        tr = self.tr
        bs = tr.cfg.batch_size
        w = tr.strategy.batch_weights(np.zeros(bs, np.int64))
        dev_state = (tr.strategy.get_device_state() if tr._thread_state
                     else None)
        # Exactly the shapes run_epoch can dispatch: every block length
        # scan_block_sizes emits for any remainder, plus the full block.
        sizes = sorted({size
                        for rem in range(self.scan_steps + 1)
                        for size in scan_block_sizes(rem, self.scan_steps)}
                       | {self.scan_steps}, reverse=True)
        for size in sizes:
            xs = {"idx": self._place_plan(np.zeros((size, bs), np.int32))}
            if w is not None:
                xs["w"] = self._place_plan(np.ones((size, bs), np.float32))
            carry = TrainCarry(*jax.tree.map(
                jnp.copy, (tr.params, tr.opt_state, tr.ef_state, dev_state,
                           tr.guard_state)))
            jax.block_until_ready(
                self._block(carry, xs, jnp.int32(0), 0.0)[1])
        return len(sizes)

    def _place_plan(self, arr: np.ndarray) -> jax.Array:
        """Ship an epoch-plan array, dim 1 (the batch dim) row-sharded over
        the data axes under a mesh."""
        ctx = self.tr.ctx
        if ctx.mesh is None:
            return jnp.asarray(arr)
        spec = P(None, *tuple(ctx.rows_spec))
        return jax.device_put(arr, NamedSharding(ctx.mesh, spec))

    def run_epoch(self, epoch: int, indices: np.ndarray, plan,
                  lr: float) -> EpochRunResult:
        tr, c = self.tr, self.tr.cfg
        plan_idx = epoch_index_plan(np.asarray(indices), c.batch_size)
        num_steps = plan_idx.shape[0]
        if num_steps == 0:
            return EpochRunResult(losses=np.zeros(0), fwd_samples=0,
                                  bwd_samples=0, host_syncs=0)
        if self._block is None:
            self._build_block()
        # Per-sample static weights are plan-time lookups (protocol
        # contract), pre-gathered here in the host loop's exact call order.
        w_rows = [tr.strategy.batch_weights(row) for row in plan_idx]
        xs = {"idx": self._place_plan(plan_idx.astype(np.int32))}
        if any(w is not None for w in w_rows):
            # None rows mean uniform; weight 1.0 is exact (loss * 1.0).
            xs["w"] = self._place_plan(np.stack(
                [np.ones(c.batch_size, np.float32) if w is None
                 else np.asarray(w, np.float32) for w in w_rows]))
        dev_state = (tr.strategy.get_device_state() if tr._thread_state
                     else None)
        carry = TrainCarry(tr.params, tr.opt_state, tr.ef_state, dev_state,
                           tr.guard_state)
        losses, bwds = [], []
        epoch_dev = jnp.int32(epoch)
        try:
            start = 0
            for size in scan_block_sizes(num_steps, self.scan_steps):
                xs_block = jax.tree.map(
                    lambda a: a[start : start + size], xs)
                carry, (block_losses, block_bwds) = self._block(
                    carry, xs_block, epoch_dev, lr)
                losses.append(block_losses)
                bwds.append(block_bwds)
                start += size
        finally:
            # The scan block donates the whole carry: hand the latest live
            # buffers back even on a mid-epoch crash, so checkpoint-on-fault
            # stays valid at scan-block granularity.  A crash *inside* a
            # dispatch (after donation) leaves nothing recoverable — don't
            # overwrite the trainer's refs with deleted buffers then.
            if _all_live(carry):
                tr.params, tr.opt_state = carry.params, carry.opt_state
                tr.ef_state = carry.ef
                if tr._thread_state:
                    tr.strategy.set_device_state(carry.sstate)
                if carry.gstate is not None:
                    tr.guard_state = carry.gstate
        # The epoch's single loss/work materialisation: per-step scalars
        # (loss + the step's backward count) were accumulated on device
        # across the scan blocks; the guard counters ride the same fetch.
        got_ls, got_bw, g = jax.device_get((losses, bwds, carry.gstate))
        ls = np.concatenate([np.asarray(x, np.float64) for x in got_ls])
        bwd = int(np.sum(np.concatenate(
            [np.asarray(x, np.int64) for x in got_bw])))
        nf = qr = consec = 0
        if g is not None:
            nf, qr, consec = (int(g.nonfinite_steps), int(g.quarantined),
                              int(g.consecutive))
        n = num_steps * c.batch_size
        return EpochRunResult(losses=ls, fwd_samples=n, bwd_samples=bwd,
                              host_syncs=0, nonfinite_steps=nf,
                              quarantined=qr, guard_consecutive=consec)
