"""Epoch-based trainer over the unified ``SampleStrategy`` protocol.

This is the training loop used by the paper-reproduction experiments and
the end-to-end examples.  It runs in two placement modes behind one config:

- **single-device** (``mesh_shape=None``, the default): the original jitted
  train/eval steps, unchanged and bit-for-bit compatible with every
  existing parity suite;
- **mesh-sharded data-parallel** (``mesh_shape=(D,)``): the train step runs
  under shard_map over a ``("data",)`` mesh (``launch/mesh.py``), with
  params/optimizer state replicated, batches and the strategy's
  ``SampleState`` row-sharded, the fused observe scatter kept sharded via
  GSPMD, and gradients combined with a *chunk-major deterministic fold*
  (see ``_jit_steps_mesh``; ``grad_allreduce="psum"`` swaps in the fast
  O(params) all-reduce) so losses and parameter trajectories are
  bit-identical for every mesh size dividing ``grad_chunks``.
  ``tests/test_mesh_trainer.py`` enforces ``(1,)`` vs ``(8,)`` equality.

Orthogonally, the per-epoch batch loop is dispatched by an *epoch engine*
(``train/engines.py``, selected per strategy capability in
``_make_engine``): the classic host loop (one jitted step per
host-assembled batch), or — for strategies whose per-batch work fits
entirely inside the jitted step — the scanned engine, which gathers batches
from device-resident data and rolls ``scan_steps`` train steps into each
``lax.scan`` dispatch.  The two engines share ``_step_core`` and are
bit-identical (``tests/test_scan_engine.py``).

(The pod-scale pjit step for the large model configs lives in
``repro.launch.train`` and shares the same Model API and ``EpochPlan``
contract.)

The trainer is strategy-agnostic: every selection method — KAKURENBO and
all baselines — arrives through ``repro.core.make_strategy`` and drives the
loop exclusively via the protocol (``plan`` / ``observe`` /
``batch_weights`` / ``fused_observe`` / ``fused_select`` /
``on_epoch_end`` / ``state_dict``).  Adding a strategy never touches this
file (``docs/adding_a_strategy.md``).

The trainer owns: jitted train/eval steps, LR scheduling (incl. Eq. 8 via
``plan.lr_scale``), work accounting (fwd/bwd sample counts — the quantity
the paper's speedup comes from), checkpoint/restart and failure injection.
"""
from __future__ import annotations

import dataclasses
import inspect
import logging
import math
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import checkpoint as ckpt
from repro.core import (
    ForgetConfig, ISWRConfig, InfoBatchConfig, KakurenboConfig, LRSchedule,
    SBConfig, GradMatchConfig, SampleStrategy, make_strategy, planops,
)
from repro.data.pipeline import Pipeline, materialize
from repro.dist.compression import compress_grads, init_error_feedback
from repro.dist.sharding import ParallelCtx, shard_map_compat
from repro.optim.optimizers import Optimizer, make_optimizer
from repro.train import guard
from repro.train.engines import HostLoopEngine, ScanEpochEngine

logger = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainConfig:
    epochs: int = 10
    batch_size: int = 64
    strategy: str = "baseline"
    optimizer: str = "sgd"
    optimizer_hp: dict = dataclasses.field(
        default_factory=lambda: {"momentum": 0.9})
    lr: LRSchedule = dataclasses.field(
        default_factory=lambda: LRSchedule(base_lr=0.05, kind="cosine",
                                           total_epochs=10, warmup_epochs=1))
    kakurenbo: KakurenboConfig = dataclasses.field(default_factory=KakurenboConfig)
    iswr: ISWRConfig = dataclasses.field(default_factory=ISWRConfig)
    forget: ForgetConfig = dataclasses.field(default_factory=ForgetConfig)
    sb: SBConfig = dataclasses.field(default_factory=SBConfig)
    gradmatch: GradMatchConfig = dataclasses.field(default_factory=GradMatchConfig)
    infobatch: InfoBatchConfig = dataclasses.field(default_factory=InfoBatchConfig)
    grad_compression: bool = False
    checkpoint_dir: str | None = None
    checkpoint_every: int = 0          # epochs; 0 = only on demand
    seed: int = 0
    eval_every: int = 1
    # Fuse the strategy's per-batch observe scatter into the jitted train
    # step (device-resident SampleState, 1 host sync/epoch). False forces
    # the legacy per-batch host observe() path — kept for the differential
    # parity test; both paths are bit-identical.
    fused_observe: bool = True
    # Fused in-step scoring: derive the per-sample (loss, PA, PC) triple
    # from the model's logits in ONE streaming online-softmax pass
    # (``kernels/ops.fused_loss_metrics`` — the Pallas kernel on TPU, its
    # fused jnp twin elsewhere) instead of the model's separate
    # logsumexp/argmax/softmax reductions.  Requires the Trainer's
    # ``logits_fn(params, batch) -> (B, V) logits``; the trainer then builds
    # the ``loss_fn`` contract itself (weighted-mean CE scalar + the
    # metrics triple feeding the fused_observe scatter), so the 1-sync/epoch
    # engine contract and the guard/quarantine paths are untouched.
    fused_scoring: bool = False
    # Mesh-sharded data-parallel mode: e.g. (8,) trains over a ("data",)
    # mesh of 8 devices (host-simulated on CPU via
    # XLA_FLAGS=--xla_force_host_platform_device_count=8). None = the
    # single-device path, byte-for-byte the pre-mesh trainer.
    mesh_shape: tuple[int, ...] | None = None
    # Gradients are reduced as a fold over this many fixed-size batch chunks
    # regardless of mesh size (each device sums its own contiguous chunk
    # range in parallel, the fold order is global-chunk-major), which makes
    # losses/trajectories bit-identical across any mesh size dividing it.
    # Must divide batch_size.
    grad_chunks: int = 8
    # How mesh gradients are combined: "fold" (default) is the chunk-major
    # deterministic fold above — O(grad_chunks x params) all-gather bytes,
    # bit-identical across mesh sizes; "psum" is the fast O(params)
    # all-reduce (one pmean over the data axis) for deployments that prefer
    # speed over cross-mesh-size reproducibility.
    grad_allreduce: str = "fold"
    # Epoch engine: "auto" runs strategies whose per-batch work fits inside
    # the jitted step (SampleStrategy.supports_scan + active fused observe;
    # all 8 registered strategies qualify) through the scanned engine, and
    # everything else (fused_observe=False, host-planned external
    # strategies) through the host loop; "scan"/"host" force one (forcing
    # "scan" on an incapable strategy raises).
    engine: str = "auto"
    # Scanned engine: place the full dataset in device memory once and
    # assemble batches by on-device gather (False forces host assembly, i.e.
    # the host-loop engine under engine="auto").
    device_data: bool = True
    # Scanned engine: train steps rolled into one lax.scan dispatch (the
    # block is unrolled, so compile time grows with this; dispatch count
    # shrinks as 1/scan_steps).
    scan_steps: int = 8
    # Numeric guard (train/guard.py): "off" traces the byte-identical
    # unguarded step; "skip_update" detects non-finite loss/grads inside
    # the jitted step, holds params/opt/EF at their pre-step values, and
    # quarantines the batch's per-sample observations so poisoned losses
    # never enter SampleState or the next epoch's hiding plan.  Counters
    # ride the device carry — host syncs stay at 1/epoch.
    guard_policy: str = "off"
    # With the guard on, abort the run (raise guard.NonFiniteError, which
    # the supervisor classifies as restartable) once this many *consecutive*
    # train steps were non-finite.  0 disables the abort; the check runs at
    # the epoch boundary, the run's only host sync.
    guard_abort_after: int = 0
    # Save checkpoints on a background thread (checkpoint.save_async).  The
    # trainer keeps the pending handle and re-raises any save failure at
    # the next checkpoint boundary — and never GCs older checkpoints until
    # the newer save is confirmed on disk.
    async_checkpoint: bool = False
    # Wire train/fault.py's StragglerMonitor into the epoch loop: per-epoch
    # worker latencies (measured, or injected via Trainer.shard_latency_fn
    # for tests/chaos) feed the monitor, and flagged stragglers shed a
    # fraction of their next epoch's rows to the other workers via
    # fault.rescale_plan/rebalance.  Off by default: the default uniform
    # latencies never flag, so the epoch plan is bit-identical to the
    # unmonitored trainer.
    straggler_mitigation: bool = False
    # World size the straggler monitor models.  0 = the mesh's data-parallel
    # degree (1 off-mesh).  Setting it >1 off-mesh simulates a multi-worker
    # deployment in one process — how the chaos suite drives slow-shard
    # scenarios without a device mesh.
    straggler_workers: int = 0


@dataclasses.dataclass
class EpochStats:
    epoch: int
    train_loss: float
    test_acc: float
    hidden_fraction: float
    fwd_samples: int
    bwd_samples: int
    lr: float
    wall_time: float
    # SampleState host round trips in the epoch's plan + batch loop (the
    # quantity the device-resident selection engine minimises; step-D
    # refresh is epoch-boundary work accounted in fwd_samples instead).
    host_syncs: int = 0
    # Which epoch engine dispatched the batch loop ("host" | "scan").
    engine: str = "host"
    # Numeric guard accounting for this epoch (0 with guard_policy="off"):
    # train steps whose update was skipped for non-finite loss/grads, and
    # per-sample observations quarantined from the fused observe scatter.
    nonfinite_steps: int = 0
    quarantined_observations: int = 0


def _fused_scoring_loss_fn(logits_fn: Callable) -> Callable:
    """Build the trainer's ``loss_fn`` contract from a raw logits function.

    The fused-scoring hot path (``TrainConfig.fused_scoring``): one
    streaming online-softmax pass over the (B, V) logits yields the full
    per-sample (ce, pa, pc) triple — Pallas kernel where it compiles, fused
    one-pass jnp twin elsewhere, analytic vjp either way (see
    ``kernels/ops.fused_loss_metrics``).  The scalar is the (optionally
    weighted) mean CE, matching the convention every hand-written loss_fn
    in the repo uses, so engines/guard/mesh code is agnostic to which
    scoring built the triple.
    """
    from repro.kernels import ops as kernel_ops

    def loss_fn(params, batch):
        logits = logits_fn(params, batch)
        ce, pa, pc = kernel_ops.fused_loss_metrics(logits, batch["labels"])
        w = batch.get("weight")
        scalar = jnp.mean(ce * w) if w is not None else jnp.mean(ce)
        return scalar, (ce, pa, pc)

    return loss_fn


class Trainer:
    """``loss_fn(params, batch) -> (scalar, (loss_vec, pa, pc))``;
    ``batch`` = dataset.get(indices) arrays (+ optional 'weight')."""

    def __init__(self, cfg: TrainConfig,
                 init_params: Callable[[jax.Array], Any],
                 loss_fn: Callable[[Any, dict], tuple] | None,
                 dataset, test_dataset=None,
                 num_classes: int | None = None,
                 feats_fn: Callable | None = None,
                 strategy: SampleStrategy | None = None,
                 logits_fn: Callable[[Any, dict], jax.Array] | None = None):
        self.cfg = cfg
        self.dataset = dataset
        self.test_dataset = test_dataset
        self.logits_fn = logits_fn
        if cfg.fused_scoring:
            if logits_fn is None:
                raise ValueError(
                    "TrainConfig.fused_scoring=True requires the Trainer's "
                    "logits_fn argument (params, batch) -> (B, V) logits — "
                    "the fused scoring pass derives (loss, PA, PC) from raw "
                    "logits, not from a pre-built loss_fn")
            self.loss_fn = _fused_scoring_loss_fn(logits_fn)
        elif loss_fn is None:
            raise ValueError(
                "loss_fn is required unless fused_scoring=True builds it "
                "from logits_fn")
        else:
            self.loss_fn = loss_fn
        self._init_params = init_params
        self.opt: Optimizer = make_optimizer(cfg.optimizer, **cfg.optimizer_hp)
        self.pipeline = Pipeline(dataset.get, cfg.batch_size)
        self.num_samples = dataset.num_samples
        self.ctx = self._build_ctx()
        # impl pinned so the checkpointed key restores on any session
        # (planops.load_key hard-codes the same impl).
        self.rng = jax.random.key(cfg.seed, impl=planops.KEY_IMPL)
        self.params = init_params(self.rng)
        self.opt_state = self.opt.init(self.params)
        self.ef_state = (init_error_feedback(self.params)
                         if cfg.grad_compression else None)
        # Numeric guard counters (train/guard.py): device-resident, threaded
        # through the step like the strategy's state, never checkpointed
        # (they are run diagnostics, not trajectory).  _guard_seen tracks
        # the cumulative totals already reported, so EpochStats get deltas.
        self.guard_state = (guard.init_guard_state()
                            if cfg.guard_policy != "off" else None)
        self._guard_seen = (0, 0)
        self._guard_host_q = 0    # legacy host-observe path's quarantines
        self._pending_save = None  # async-checkpoint handle, see save_checkpoint
        self._place()
        self.epoch = 0
        self.history: list[EpochStats] = []
        # Straggler mitigation (train/fault.py): per-epoch worker latencies
        # feed the monitor; tests/chaos inject skew via shard_latency_fn.
        if cfg.straggler_mitigation:
            from repro.train import fault as _fault
            self._straggler = _fault.StragglerMonitor(
                world_size=cfg.straggler_workers
                or max(self.ctx.dp_size, 1))
        else:
            self._straggler = None
        self.shard_latency_fn: Callable[[int], list[float]] | None = None
        # ctx reaches strategies whose constructor declares it (kakurenbo,
        # random): their SampleState is row-sharded and their plan step runs
        # the cross-shard selection. Other strategies stay host/uncommitted
        # and are resharded on the fly by the jitted mesh step.
        self.strategy = strategy or make_strategy(
            cfg.strategy, self.num_samples, cfg=cfg, seed=cfg.seed,
            num_classes=num_classes, total_epochs=cfg.epochs, ctx=self.ctx)
        self.feats_fn = feats_fn
        self._device_data = None       # lazy cache, see device_data()
        self._jit_steps()

    def _build_ctx(self) -> ParallelCtx:
        c = self.cfg
        if c.engine not in ("auto", "scan", "host"):
            raise ValueError(
                f"TrainConfig.engine={c.engine!r}: must be 'auto', 'scan' or "
                "'host'")
        if c.grad_allreduce not in ("fold", "psum"):
            raise ValueError(
                f"TrainConfig.grad_allreduce={c.grad_allreduce!r}: must be "
                "'fold' (deterministic chunk-major fold) or 'psum' (fast "
                "O(params) all-reduce)")
        if c.guard_policy not in guard.GUARD_POLICIES:
            raise ValueError(
                f"TrainConfig.guard_policy={c.guard_policy!r}: must be one "
                f"of {guard.GUARD_POLICIES}")
        if c.guard_abort_after and c.guard_policy == "off":
            raise ValueError(
                "TrainConfig.guard_abort_after requires "
                "guard_policy='skip_update' — with the guard off no "
                "non-finite steps are ever counted")
        if not c.mesh_shape:
            return ParallelCtx()
        from repro.launch.mesh import make_data_mesh
        num_devices = math.prod(c.mesh_shape)
        if c.batch_size % c.grad_chunks:
            raise ValueError(
                f"batch_size={c.batch_size} must be a multiple of "
                f"grad_chunks={c.grad_chunks}")
        if c.grad_chunks % num_devices:
            raise ValueError(
                f"grad_chunks={c.grad_chunks} must be a multiple of the mesh "
                f"size {num_devices} — it is the fixed reduction layout that "
                "keeps losses bit-identical across mesh sizes")
        return ParallelCtx(mesh=make_data_mesh(num_devices))

    def _place(self) -> None:
        """Replicate the train state over the mesh (no-op off-mesh).

        Called whenever params/opt/ef are (re)built on the host default
        device: init, FORGET's reinit, checkpoint restore.
        """
        self.params = self.ctx.replicate(self.params)
        self.opt_state = self.ctx.replicate(self.opt_state)
        if self.ef_state is not None:
            self.ef_state = self.ctx.replicate(self.ef_state)
        if self.guard_state is not None:
            # Guard counters summarise the *global* step: replicated.
            self.guard_state = self.ctx.replicate(self.guard_state)

    # Legacy alias: tests and notebooks reach sampler state via tr.sampler.
    @property
    def sampler(self) -> SampleStrategy:
        return self.strategy

    # ------------------------------------------------------------------ setup

    def _jit_steps(self):
        # Fused hooks: the strategy's per-batch work runs inside the jitted
        # train step, so its device state never bounces to the host
        # mid-epoch.  ``fused_observe`` is the bookkeeping scatter (gated by
        # TrainConfig.fused_observe for the legacy-parity path);
        # ``fused_select`` is the in-step forward-then-mask selection (SB) —
        # always active, it has no host equivalent.  Either hook requires
        # the strategy to expose device state, which the engines then thread
        # through the epoch.
        has_dev = self.strategy.get_device_state() is not None
        fuse = (self.strategy.fused_observe
                if self.cfg.fused_observe and has_dev else None)
        fsel = self.strategy.fused_select if has_dev else None
        self._fuse, self._fsel = fuse, fsel
        self._thread_state = fuse is not None or fsel is not None
        if self.ctx.mesh is not None:
            self._jit_steps_mesh(fuse, fsel)
            self.engine = self._make_engine()
            return
        opt, loss_fn, compress = self.opt, self.loss_fn, self.cfg.grad_compression
        batch_size = self.cfg.batch_size
        guarded = self.cfg.guard_policy != "off"
        fuse_valid = (guarded and fuse is not None
                      and "valid" in inspect.signature(fuse).parameters)

        # The un-jitted step math, shared by both epoch engines: the host
        # loop jits it per batch, the scanned engine inlines it into its
        # lax.scan blocks — one compilation contract, so the engines are
        # bit-identical by construction.  The step reports its backward
        # sample count as a device scalar (the full batch, or the fused
        # select's surviving count) so work accounting never syncs mid-epoch.
        # ``guarded`` branches are trace-time: with guard_policy="off" the
        # compiled step is byte-identical to the unguarded trainer (gstate
        # is the empty None pytree then).
        def train_step(params, opt_state, ef, sstate, gstate, batch, indices,
                       epoch, lr):
            if fsel is not None:
                # Forward-only loss at the current params drives the in-step
                # selection; the chosen weights mask the backward pass.
                _, (lv0, _, _) = loss_fn(params, batch)
                if guarded:
                    # A non-finite selection loss would poison the select
                    # state's history: hold the state and fall back to
                    # training the full batch (where/select never propagate
                    # the discarded branch).
                    ok0 = jnp.all(jnp.isfinite(lv0))
                    w_new, s_new = fsel(sstate, lv0)
                    sstate = guard.select(ok0, s_new, sstate)
                    w_sel = jnp.where(ok0, w_new, jnp.ones_like(w_new))
                else:
                    w_sel, sstate = fsel(sstate, lv0)
                batch = dict(batch)
                batch["weight"] = (batch["weight"] * w_sel
                                   if "weight" in batch else w_sel)
                bwd = jnp.count_nonzero(w_sel).astype(jnp.int32)
            else:
                bwd = jnp.int32(batch_size)
            (scalar, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            if guarded:
                ok = guard.all_finite(scalar, grads)
                if compress:
                    # Zero *before* compression so a poisoned gradient never
                    # enters the error-feedback residual; the select below
                    # then restores the residual (and params/opt) bit-exactly.
                    # Without compression nothing stateful sees the raw
                    # grads before the select, so the O(params) zeroing pass
                    # is skipped (the select alone discards the bad update —
                    # ``where`` never propagates the dropped branch's NaNs).
                    grads = guard.zero_if(~ok, grads)
                prev = (params, opt_state, ef)
            if compress:
                grads, ef = compress_grads(grads, ef)
            params, opt_state = opt.update(grads, opt_state, params, lr)
            if guarded:
                params, opt_state, ef = guard.select(
                    ok, (params, opt_state, ef), prev)
            if fuse is not None:
                lv, pa, pc = metrics
                if fuse_valid:
                    # Score quarantine: per-sample observations with
                    # non-finite loss/confidence scatter their previous
                    # values back (core/state.py), keeping the next epoch's
                    # hiding plan finite.
                    valid = guard.observation_valid(lv, pc)
                    sstate = fuse(sstate, indices, lv, pa, pc, epoch,
                                  valid=valid)
                    quarantined = jnp.sum(~valid).astype(jnp.int32)
                elif guarded:
                    # External fused observe without a ``valid`` parameter:
                    # degrade to all-or-nothing — any bad observation skips
                    # the whole batch's scatter.
                    valid = guard.observation_valid(lv, pc)
                    s_new = fuse(sstate, indices, lv, pa, pc, epoch)
                    sstate = guard.select(jnp.all(valid), s_new, sstate)
                    quarantined = jnp.sum(~valid).astype(jnp.int32)
                else:
                    sstate = fuse(sstate, indices, lv, pa, pc, epoch)
            if guarded:
                if fuse is None:
                    quarantined = jnp.int32(0)
                gstate = guard.update_counters(gstate, ok, quarantined)
            return (params, opt_state, ef, sstate, gstate, scalar, bwd,
                    metrics)

        def eval_step(params, batch):
            _, metrics = loss_fn(params, batch)
            return metrics

        self._step_core = train_step
        self._train_step = jax.jit(train_step, donate_argnums=(0, 1, 2, 3, 4))
        self._eval_step = jax.jit(eval_step)
        self.engine = self._make_engine()

    def _make_engine(self):
        """Pick the epoch engine for this (strategy, config) pair.

        The scanned engine requires every per-batch hook to be expressible
        on device: ``SampleStrategy.supports_scan`` plus an *active* fused
        observe whenever the strategy observes at all
        (``TrainConfig.fused_observe=False`` forces the host loop, keeping
        the legacy differential-parity path intact).  There is no per-
        strategy branch here: all 8 registered strategies scan — loss-
        dependent selection rides the in-step ``fused_select`` hook.
        """
        s = self.strategy
        observes = type(s).observe is not SampleStrategy.observe
        scannable = s.supports_scan and (self._fuse is not None
                                         or not observes)
        mode = self.cfg.engine
        if mode == "scan" and not scannable:
            raise ValueError(
                f"engine='scan' but strategy {s.name!r} cannot run scanned "
                "epochs (host-side observe without an active fused_observe) "
                "— use engine='auto' or 'host'")
        if mode == "scan" and not self.cfg.device_data:
            raise ValueError(
                "engine='scan' requires device_data=True — the scanned "
                "engine assembles batches by gathering from the "
                "device-resident dataset")
        use_scan = (mode == "scan" or (mode == "auto" and scannable
                                       and self.cfg.device_data
                                       and self.cfg.scan_steps > 0))
        return ScanEpochEngine(self) if use_scan else HostLoopEngine(self)

    def device_data(self) -> dict:
        """The full dataset as device arrays, placed once and cached
        (row-sharded over the data axes under a mesh when N divides the
        data-parallel degree, replicated otherwise) — the gather source for
        the scanned engine's on-device batch assembly."""
        if self._device_data is None:
            arrays = (self.dataset.arrays() if hasattr(self.dataset, "arrays")
                      else materialize(self.dataset.get, self.num_samples))
            if (self.ctx.mesh is not None
                    and self.num_samples % self.ctx.dp_size == 0):
                self._device_data = self.ctx.shard_rows(
                    {k: jnp.asarray(v) for k, v in arrays.items()})
            else:
                self._device_data = self.ctx.replicate(
                    {k: jnp.asarray(v) for k, v in arrays.items()})
        return self._device_data

    def _jit_steps_mesh(self, fuse, fsel=None):
        """Mesh-sharded train/eval steps (``TrainConfig.mesh_shape``).

        The train step is a shard_map over the ``("data",)`` axis wrapped in
        one jit with the (GSPMD) fused observe scatter:

        - params / optimizer state / EF residuals are replicated; batches,
          per-sample metrics and ``SampleState`` are row-sharded.
        - ``grad_allreduce="fold"`` (default): the global batch is viewed as
          ``grad_chunks`` fixed-size chunks in batch order.  Each device
          computes per-chunk loss/grads for its contiguous chunk range *in
          parallel*, then partial results are all-gathered and folded
          left-to-right in global chunk order.  The reduction tree therefore
          depends only on ``grad_chunks`` — never on the mesh size — which
          is what makes losses and parameter trajectories bit-identical
          between ``(1,)`` and ``(8,)`` meshes
          (``tests/test_mesh_trainer.py``).  The all-gather costs
          O(grad_chunks × params) wire bytes versus a psum's O(params).
        - ``grad_allreduce="psum"``: the fast mode — each device takes one
          loss/grad over its whole batch shard and gradients are combined
          with a single ``pmean`` over the data axis.  O(params) wire bytes
          and no chunk loop, but the reduction tree now depends on the mesh
          size, so results are reproducible per mesh size rather than across
          mesh sizes.
        - Error-feedback compression (``grad_compression``) quantizes the
          folded (replicated) gradients before the optimizer update — the
          same contract as the single-device step, so it is deterministic
          and mesh-size-invariant too.
        - The fused observe runs as a *global* scatter on the row-sharded
          state after the shard_map core: XLA partitions it into an O(B)
          metrics gather + shard-local writes (see
          ``core/state.py::scatter_observations``), and a sharding
          constraint keeps the state from ever gathering to one device.
        - The fused select (SB) runs *before* the shard_map core: a
          forward-only GSPMD pass over the sharded batch yields the (B,)
          loss (per-sample, so bit-identical across mesh sizes — the
          ``_eval_step`` argument), which is constrained *replicated*
          together with the select state so the history/percentile/draw
          math is the single-device computation on every shard; the chosen
          weights are constrained back to rows and enter the batch.
        """
        ctx = self.ctx
        mesh = ctx.mesh
        opt, loss_fn, compress = self.opt, self.loss_fn, self.cfg.grad_compression
        guarded = self.cfg.guard_policy != "off"
        fuse_valid = (guarded and fuse is not None
                      and "valid" in inspect.signature(fuse).parameters)
        C = self.cfg.grad_chunks
        D = ctx.dp_size
        local_chunks = C // D
        chunk_rows = self.cfg.batch_size // C

        # Numeric guard inside the shard_map cores: the check runs on the
        # *reduced* gradients (post-fold / post-pmean), which are already
        # replicated — so ``ok`` is the same device bool on every shard and
        # the held/advanced select cannot diverge across the mesh.  The
        # zero-before-compress / select-after-update containment is the
        # single-device step's, verbatim.
        def _guard_update(params, opt_state, ef, scalar, grads, lr):
            ok = guard.all_finite(scalar, grads)
            if compress:
                # Only the EF residual sees the raw grads pre-select; zero
                # them first so it is never poisoned.  Uncompressed, the
                # post-update select alone contains the fault.
                grads = guard.zero_if(~ok, grads)
            prev = (params, opt_state, ef)
            if compress:
                grads, ef = compress_grads(grads, ef)
            params, opt_state = opt.update(grads, opt_state, params, lr)
            params, opt_state, ef = guard.select(
                ok, (params, opt_state, ef), prev)
            return params, opt_state, ef, ok

        def local_core_psum(params, opt_state, ef, batch, lr):
            # Fast mode: one loss/grad over the local rows, one O(params)
            # pmean.  Equal shard sizes make the mean-of-local-means the
            # exact global-batch mean in real arithmetic; in floats the
            # reduction tree depends on D, hence no cross-mesh-size
            # bit-identity promise (grad_allreduce="fold" has that).
            (scalar, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads = jax.lax.pmean(grads, "data")
            scalar = jax.lax.pmean(scalar, "data")
            if guarded:
                params, opt_state, ef, ok = _guard_update(
                    params, opt_state, ef, scalar, grads, lr)
                return params, opt_state, ef, scalar, metrics, ok
            if compress:
                grads, ef = compress_grads(grads, ef)
            params, opt_state = opt.update(grads, opt_state, params, lr)
            return params, opt_state, ef, scalar, metrics

        def local_core(params, opt_state, ef, batch, lr):
            # Local rows: (B/D, ...) = ``local_chunks`` contiguous global
            # chunks (chunk-major layout, so device order == chunk order).
            grads_c, loss_c, mets = [], [], []
            for i in range(local_chunks):
                cb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * chunk_rows, chunk_rows, 0), batch)
                (s, m), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, cb)
                grads_c.append(g)
                loss_c.append(s)
                mets.append(m)
            # Stack local per-chunk partials, gather across devices, fold in
            # global chunk order. reshape((C,)+...) turns the gathered
            # (D, local_chunks, ...) into chunk-major (C, ...).
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *grads_c)
            gathered = jax.lax.all_gather(
                (stacked, jnp.stack(loss_c)), "data")

            def fold(x):
                x = x.reshape((C,) + x.shape[2:])
                acc = x[0]
                for j in range(1, C):
                    acc = acc + x[j]
                return acc

            grads = jax.tree.map(fold, gathered[0])
            # Every chunk scalar is a chunk-mean of the user loss_fn, so the
            # fold/C is exactly the global-batch mean (equal chunk sizes).
            scalar = fold(gathered[1]) / C
            grads = jax.tree.map(lambda g: g / C, grads)
            metrics = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *mets)
            if guarded:
                params, opt_state, ef, ok = _guard_update(
                    params, opt_state, ef, scalar, grads, lr)
                return params, opt_state, ef, scalar, metrics, ok
            if compress:
                grads, ef = compress_grads(grads, ef)
            params, opt_state = opt.update(grads, opt_state, params, lr)
            return params, opt_state, ef, scalar, metrics

        core = shard_map_compat(
            local_core_psum if self.cfg.grad_allreduce == "psum"
            else local_core,
            mesh=mesh,
            in_specs=(P(), P(), P(), P("data"), P()),
            out_specs=(P(), P(), P(), P(), P("data"))
            + ((P(),) if guarded else ()))

        batch_size = self.cfg.batch_size
        rep_sharding = NamedSharding(mesh, P())
        rows_sharding = NamedSharding(mesh, ctx.rows_spec)

        def train_step(params, opt_state, ef, sstate, gstate, batch, indices,
                       epoch, lr):
            if fsel is not None:
                _, (lv0, _, _) = loss_fn(params, batch)
                # Replicate the loss vector and the (global-history) select
                # state: the selection math is then the exact single-device
                # computation on every shard — mesh-size-invariant.
                lv0 = jax.lax.with_sharding_constraint(lv0, rep_sharding)
                sstate = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x, rep_sharding), sstate)
                if guarded:
                    # Same containment as the single-device step: a
                    # non-finite selection loss holds the select state and
                    # trains the full batch.  lv0 is replicated, so ok0 is
                    # too — no cross-shard divergence.
                    ok0 = jnp.all(jnp.isfinite(lv0))
                    w_new, s_new = fsel(sstate, lv0)
                    sstate = guard.select(ok0, s_new, sstate)
                    w_sel = jnp.where(ok0, w_new, jnp.ones_like(w_new))
                else:
                    w_sel, sstate = fsel(sstate, lv0)
                bwd = jnp.count_nonzero(w_sel).astype(jnp.int32)
                w_sel = jax.lax.with_sharding_constraint(w_sel, rows_sharding)
                batch = dict(batch)
                batch["weight"] = (batch["weight"] * w_sel
                                   if "weight" in batch else w_sel)
            else:
                bwd = jnp.int32(batch_size)
            if guarded:
                params, opt_state, ef, scalar, metrics, ok = core(
                    params, opt_state, ef, batch, lr)
            else:
                params, opt_state, ef, scalar, metrics = core(
                    params, opt_state, ef, batch, lr)
            if fuse is not None:
                lv, pa, pc = metrics
                if fuse_valid:
                    # Score quarantine over the row-sharded metrics: the
                    # masked scatter partitions exactly like the unguarded
                    # one (O(B) gathers + shard-local writes).
                    valid = guard.observation_valid(lv, pc)
                    sstate = fuse(sstate, indices, lv, pa, pc, epoch,
                                  valid=valid)
                    quarantined = jnp.sum(~valid).astype(jnp.int32)
                elif guarded:
                    valid = guard.observation_valid(lv, pc)
                    s_new = fuse(sstate, indices, lv, pa, pc, epoch)
                    sstate = guard.select(jnp.all(valid), s_new, sstate)
                    quarantined = jnp.sum(~valid).astype(jnp.int32)
                else:
                    sstate = fuse(sstate, indices, lv, pa, pc, epoch)
                sstate = ctx.constrain_rows(sstate)
            if guarded:
                if fuse is None:
                    quarantined = jnp.int32(0)
                gstate = guard.update_counters(gstate, ok, quarantined)
            return (params, opt_state, ef, sstate, gstate, scalar, bwd,
                    metrics)

        def eval_step(params, batch):
            _, metrics = loss_fn(params, batch)
            return metrics

        self._step_core = train_step
        self._train_step = jax.jit(train_step, donate_argnums=(0, 1, 2, 3, 4))
        # Forward-only metrics are per-sample (no cross-sample reductions in
        # the loss vector), so plain GSPMD over the sharded batch is already
        # bit-identical across mesh sizes; no chunking needed.
        self._eval_step = jax.jit(
            eval_step,
            in_shardings=(NamedSharding(mesh, P()),
                          NamedSharding(mesh, P("data"))))

    # ------------------------------------------------------------------ epochs

    def _collect_feats(self):
        feats, labels = [], []
        for idx, batch in self.pipeline.batches(np.arange(self.num_samples)):
            p = self.feats_fn(self.params, batch)
            feats.append(np.asarray(p))
            labels.append(batch["labels"])
        return np.concatenate(feats), np.concatenate(labels)

    def _epoch_indices(self, epoch: int):
        """Returns (visible indices, EpochPlan) for this epoch."""
        self.strategy.prepare(
            epoch, self._collect_feats if self.feats_fn is not None else None)
        plan = self.strategy.plan(epoch)
        if plan.reinit_model:
            # e.g. FORGET: restart training from scratch on the pruned set.
            self.params = self._init_params(self.rng)
            self.opt_state = self.opt.init(self.params)
            self._place()
        return plan.visible_indices, plan

    def _rebalanced_order(self, indices: np.ndarray) -> np.ndarray:
        """Re-slice an epoch's visible order when stragglers are flagged.

        The plan's global order is deterministically split into per-worker
        views (``fault.rescale_plan``/``worker_slice``), flagged stragglers
        shed a fraction of their rows to the fastest workers
        (``StragglerMonitor.rebalance``), and the views are re-flattened —
        rebalanced workers first, then the slice-trimmed tail, so every
        visible sample still trains exactly once.  With no straggler
        flagged (the default: uniform latencies) this returns ``indices``
        unchanged — bit-identical plans.
        """
        from repro.train import fault as _fault
        mon = self._straggler
        if mon.world_size <= 1 or not mon.stragglers().any():
            return indices
        idx = np.asarray(indices)
        bs = self.cfg.batch_size
        chunk = mon.world_size * bs
        n_used = (len(idx) // chunk) * chunk
        rp = _fault.rescale_plan(idx[:n_used], mon.world_size, bs)
        per_worker = mon.rebalance(rp.per_worker)
        logger.warning(
            "straggler mitigation: stragglers %s — rebalanced worker rows "
            "%s", np.nonzero(mon.stragglers())[0].tolist(),
            [len(w) for w in per_worker])
        return np.concatenate([*per_worker, idx[n_used:]])

    def run_epoch(self, epoch: int) -> EpochStats:
        c = self.cfg
        t0 = time.perf_counter()
        indices, plan = self._epoch_indices(epoch)
        lr = float(c.lr(epoch)) * plan.lr_scale
        # The batch loop is the engine's job (train/engines.py): the host
        # loop dispatches one jitted step per batch; the scanned engine
        # gathers batches on device and dispatches scan_steps-sized blocks.
        if self._straggler is not None:
            indices = self._rebalanced_order(indices)
        res = self.engine.run_epoch(epoch, indices, plan, lr)
        if self._straggler is not None:
            # Feed the monitor this epoch's per-worker latencies.  Measured
            # wall time is uniform across simulated workers (one process),
            # so the default never flags and the plan stays bit-identical;
            # tests/chaos inject skew through shard_latency_fn.
            w = self._straggler.world_size
            lat = (self.shard_latency_fn(epoch)
                   if self.shard_latency_fn is not None
                   else [(time.perf_counter() - t0) / w] * w)
            self._straggler.record_epoch(lat)
        fwd, bwd = res.fwd_samples, res.bwd_samples
        # Guard accounting: the engine reports the device counters'
        # cumulative totals (fetched inside its single epoch-end
        # device_get); diff against what was already reported so the stats
        # are per-epoch.  The abort policy also lives here — the epoch
        # boundary is the run's only host sync.
        nonfinite = quarantined = 0
        if self.guard_state is not None:
            nonfinite = res.nonfinite_steps - self._guard_seen[0]
            quarantined = res.quarantined - self._guard_seen[1]
            self._guard_seen = (res.nonfinite_steps, res.quarantined)
            if nonfinite:
                logger.warning(
                    "numeric guard: epoch %d skipped %d non-finite step(s), "
                    "quarantined %d observation(s) (consecutive=%d)",
                    epoch, nonfinite, quarantined, res.guard_consecutive)
            if (c.guard_abort_after
                    and res.guard_consecutive >= c.guard_abort_after):
                raise guard.NonFiniteError(
                    f"{res.guard_consecutive} consecutive non-finite train "
                    f"steps at epoch {epoch} (guard_abort_after="
                    f"{c.guard_abort_after}) — params are held at the last "
                    "finite update; restart from the latest checkpoint")
        if plan.needs_refresh:
            # KAKURENBO step D: forward-only refresh of the hidden list.
            def fwd_fn(idx):
                return self._eval_step(self.params, self.dataset.get(idx))
            fwd += self.strategy.on_epoch_end(plan, fwd_fn, c.batch_size)
        acc = self.evaluate() if (self.test_dataset is not None
                                  and epoch % c.eval_every == 0) else float("nan")
        stats = EpochStats(
            epoch=epoch,
            train_loss=(float(np.mean(res.losses)) if len(res.losses)
                        else float("nan")),
            test_acc=acc,
            hidden_fraction=plan.hidden_fraction,
            fwd_samples=fwd, bwd_samples=bwd, lr=lr,
            wall_time=time.perf_counter() - t0,
            host_syncs=plan.host_syncs + res.host_syncs,
            engine=self.engine.name,
            nonfinite_steps=nonfinite,
            quarantined_observations=quarantined)
        self.history.append(stats)
        self.epoch = epoch + 1
        if (c.checkpoint_dir and c.checkpoint_every
                and (epoch + 1) % c.checkpoint_every == 0):
            self.save_checkpoint()
        return stats

    def run(self, epochs: int | None = None,
            fail_at_epoch: int | None = None) -> list[EpochStats]:
        """Run remaining epochs; ``fail_at_epoch`` injects a simulated crash
        (raises) for the fault-tolerance tests."""
        total = epochs or self.cfg.epochs
        while self.epoch < total:
            if fail_at_epoch is not None and self.epoch == fail_at_epoch:
                raise RuntimeError(f"injected failure at epoch {self.epoch}")
            self.run_epoch(self.epoch)
        # Surface a failed trailing async save before reporting success.
        self.finish_checkpoints()
        return self.history

    # ------------------------------------------------------------------ eval

    def evaluate(self) -> float:
        ds = self.test_dataset
        correct = total = 0
        for idx, batch in Pipeline(ds.get, self.cfg.batch_size).batches(
                np.arange(ds.num_samples)):
            _, pa, _ = self._eval_step(self.params, batch)
            correct += int(np.sum(np.asarray(pa)))
            total += len(idx)
        return correct / max(total, 1)

    # ------------------------------------------------------------------ fault tolerance

    def _ckpt_tree(self, strategy_sd: dict | None = None):
        sd = strategy_sd or self.strategy.state_dict()
        # The trainer's init key rides the checkpoint: FORGET-style
        # reinit_model restarts must draw the same fresh params after a
        # restore even if the restoring process was configured with a
        # different seed (restore always wins over construction seeds).
        tree = {"params": self.params, "opt_state": self.opt_state,
                "strategy": sd["arrays"],
                "rng": planops.key_data(self.rng)}
        if self.ef_state is not None:
            # The error-feedback residual is part of the trajectory: without
            # it a compressed-gradient restart re-quantizes from zero carry
            # and silently diverges from the uninterrupted run.  Only added
            # when compression is on, so uncompressed checkpoints keep the
            # legacy leaf set.
            tree["ef"] = self.ef_state
        return tree

    def save_checkpoint(self) -> str | None:
        if not self.cfg.checkpoint_dir:
            return None
        # The strategy's host state (epoch-shuffle / with-replacement RNGs,
        # restart flags) must be checkpointed too — without it a restart
        # re-shuffles differently and the resumed trajectory silently
        # diverges from the uninterrupted one
        # (caught by test_checkpoint_restart_bit_exact).
        sd = self.strategy.state_dict()
        meta = {"epoch": self.epoch, "strategy": sd["host"]}
        if self.cfg.async_checkpoint:
            # Join the previous handle first: a failed background save must
            # surface *before* we start the next one — and older
            # checkpoints are only GC'd after the newer save is confirmed
            # on disk (keep=None disables save()'s own GC on the thread),
            # so a crash chain can always fall back to a real checkpoint.
            self.finish_checkpoints()
            self._pending_save = ckpt.save_async(
                self.cfg.checkpoint_dir, self.epoch, self._ckpt_tree(sd),
                metadata=meta, keep=None)
            return self._pending_save.path
        return ckpt.save(self.cfg.checkpoint_dir, self.epoch,
                         self._ckpt_tree(sd), metadata=meta)

    def finish_checkpoints(self) -> None:
        """Join any pending async save — re-raising its failure — then GC
        superseded checkpoints.  Called between async saves and at the end
        of ``run()``; safe to call any time."""
        if self._pending_save is None:
            return
        self._pending_save.join()
        self._pending_save = None
        ckpt.gc(self.cfg.checkpoint_dir)

    def restore_latest(self) -> bool:
        if not self.cfg.checkpoint_dir:
            return False
        try:
            res = ckpt.restore_latest(self.cfg.checkpoint_dir,
                                      self._ckpt_tree())
        except ValueError as e:
            # e.g. a pre-strategy-format checkpoint with a different leaf set
            raise ValueError(
                f"incompatible checkpoint in {self.cfg.checkpoint_dir!r} "
                f"(old format?): {e}") from e
        if res is None:
            return False
        tree, meta, step = res
        if "strategy" not in meta:
            raise ValueError(
                f"checkpoint step {step} predates the strategy state format "
                "(no 'strategy' metadata) — cannot restore RNG state")
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        if self.ef_state is not None:
            self.ef_state = tree["ef"]
        self.rng = planops.load_key(tree["rng"])
        self._place()
        self.strategy.load_state_dict(
            {"arrays": tree["strategy"], "host": meta["strategy"]})
        self.epoch = meta["epoch"]
        return True
