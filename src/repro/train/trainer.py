"""Epoch-based trainer integrating KAKURENBO and every baseline strategy.

This is the host-side training loop used by the paper-reproduction
experiments and the end-to-end examples (single process; the pod-scale pjit
train step lives in ``repro.launch.train`` and shares the same Model API).

Strategies: baseline | kakurenbo | iswr | forget | sb | gradmatch |
random | infobatch.
The trainer owns: jitted train/eval steps, the sampler, LR scheduling
(incl. Eq. 8), work accounting (fwd/bwd sample counts — the quantity the
paper's speedup comes from), checkpoint/restart and failure injection.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.core import (
    ForgetConfig, ForgetSampler, ISWRConfig, ISWRSampler, InfoBatchConfig,
    InfoBatchSampler, KakurenboConfig, KakurenboSampler, LRSchedule,
    SBConfig, SelectiveBackprop, GradMatchConfig, GradMatchSampler,
)
from repro.data.pipeline import Pipeline
from repro.dist.compression import compress_grads, init_error_feedback
from repro.optim.optimizers import Optimizer, make_optimizer


@dataclasses.dataclass
class TrainConfig:
    epochs: int = 10
    batch_size: int = 64
    strategy: str = "baseline"
    optimizer: str = "sgd"
    optimizer_hp: dict = dataclasses.field(
        default_factory=lambda: {"momentum": 0.9})
    lr: LRSchedule = dataclasses.field(
        default_factory=lambda: LRSchedule(base_lr=0.05, kind="cosine",
                                           total_epochs=10, warmup_epochs=1))
    kakurenbo: KakurenboConfig = dataclasses.field(default_factory=KakurenboConfig)
    iswr: ISWRConfig = dataclasses.field(default_factory=ISWRConfig)
    forget: ForgetConfig = dataclasses.field(default_factory=ForgetConfig)
    sb: SBConfig = dataclasses.field(default_factory=SBConfig)
    gradmatch: GradMatchConfig = dataclasses.field(default_factory=GradMatchConfig)
    infobatch: InfoBatchConfig = dataclasses.field(default_factory=InfoBatchConfig)
    grad_compression: bool = False
    checkpoint_dir: str | None = None
    checkpoint_every: int = 0          # epochs; 0 = only on demand
    seed: int = 0
    eval_every: int = 1


@dataclasses.dataclass
class EpochStats:
    epoch: int
    train_loss: float
    test_acc: float
    hidden_fraction: float
    fwd_samples: int
    bwd_samples: int
    lr: float
    wall_time: float


class Trainer:
    """``loss_fn(params, batch) -> (scalar, (loss_vec, pa, pc))``;
    ``batch`` = dataset.get(indices) arrays (+ optional 'weight')."""

    def __init__(self, cfg: TrainConfig,
                 init_params: Callable[[jax.Array], Any],
                 loss_fn: Callable[[Any, dict], tuple],
                 dataset, test_dataset=None,
                 num_classes: int | None = None,
                 feats_fn: Callable | None = None):
        self.cfg = cfg
        self.dataset = dataset
        self.test_dataset = test_dataset
        self.loss_fn = loss_fn
        self._init_params = init_params
        self.opt: Optimizer = make_optimizer(cfg.optimizer, **cfg.optimizer_hp)
        self.pipeline = Pipeline(dataset.get, cfg.batch_size)
        self.num_samples = dataset.num_samples
        self.rng = jax.random.key(cfg.seed)
        self.params = init_params(self.rng)
        self.opt_state = self.opt.init(self.params)
        self.ef_state = (init_error_feedback(self.params)
                         if cfg.grad_compression else None)
        self.epoch = 0
        self.history: list[EpochStats] = []
        self._build_sampler(num_classes)
        self.feats_fn = feats_fn
        self._jit_steps()

    # ------------------------------------------------------------------ setup

    def _build_sampler(self, num_classes):
        c, n = self.cfg, self.num_samples
        self.sb = None
        if c.strategy in ("baseline",):
            self.sampler = None
        elif c.strategy == "kakurenbo":
            self.sampler = KakurenboSampler(n, c.kakurenbo, c.seed)
        elif c.strategy == "random":
            kc = dataclasses.replace(c.kakurenbo)
            self.sampler = KakurenboSampler(n, kc, c.seed)
        elif c.strategy == "iswr":
            self.sampler = ISWRSampler(n, c.iswr, c.seed)
        elif c.strategy == "forget":
            self.sampler = ForgetSampler(n, c.forget, c.seed)
        elif c.strategy == "sb":
            self.sampler = None
            self.sb = SelectiveBackprop(c.sb, c.seed)
        elif c.strategy == "gradmatch":
            assert num_classes is not None
            self.sampler = GradMatchSampler(n, num_classes, c.gradmatch, c.seed)
        elif c.strategy == "infobatch":
            ib = dataclasses.replace(c.infobatch, total_epochs=c.epochs)
            self.sampler = InfoBatchSampler(n, ib, c.seed)
        else:
            raise ValueError(f"unknown strategy {c.strategy!r}")
        self._shuffle_rng = np.random.default_rng(c.seed + 1)

    def _jit_steps(self):
        opt, loss_fn, compress = self.opt, self.loss_fn, self.cfg.grad_compression

        def train_step(params, opt_state, ef, batch, lr):
            (scalar, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            if compress:
                grads, ef = compress_grads(grads, ef)
            params, opt_state = opt.update(grads, opt_state, params, lr)
            return params, opt_state, ef, scalar, metrics

        def eval_step(params, batch):
            _, metrics = loss_fn(params, batch)
            return metrics

        self._train_step = jax.jit(train_step, donate_argnums=(0, 1, 2))
        self._eval_step = jax.jit(eval_step)

    # ------------------------------------------------------------------ epochs

    def _epoch_indices(self, epoch: int):
        """Returns (indices, plan_or_None) honoring the strategy."""
        c = self.cfg
        if c.strategy in ("baseline", "sb"):
            idx = np.arange(self.num_samples)
            self._shuffle_rng.shuffle(idx)
            return idx, None
        if c.strategy in ("kakurenbo", "random"):
            if c.strategy == "random":
                self._randomize_losses()
            plan = self.sampler.begin_epoch(epoch)
            return plan.visible_indices, plan
        if c.strategy in ("iswr", "infobatch"):
            return self.sampler.begin_epoch(epoch), None
        if c.strategy == "forget":
            idx = self.sampler.begin_epoch(epoch)
            if self.sampler.should_restart:
                # FORGET restarts training from scratch on the pruned set.
                self.params = self._init_params(self.rng)
                self.opt_state = self.opt.init(self.params)
            return idx, None
        if c.strategy == "gradmatch":
            if self.feats_fn is not None and epoch % c.gradmatch.interval == 0:
                feats, labels = self._collect_feats()
                self.sampler.maybe_reselect(epoch, feats, labels)
            return self.sampler.begin_epoch(), None
        raise AssertionError

    def _randomize_losses(self):
        """'random' baseline (App. C.4): importance = iid uniform."""
        from repro.core.state import SampleState
        import dataclasses as dc
        n = self.num_samples
        self.sampler.state = dc.replace(
            self.sampler.state,
            loss=jnp.asarray(self._shuffle_rng.random(n), jnp.float32),
            pa=jnp.ones((n,), bool),
            pc=jnp.ones((n,), jnp.float32),
            seen=jnp.zeros((n,), jnp.int32))

    def _collect_feats(self):
        feats, labels = [], []
        for idx, batch in self.pipeline.batches(np.arange(self.num_samples)):
            p = self.feats_fn(self.params, batch)
            feats.append(np.asarray(p))
            labels.append(batch["labels"])
        return np.concatenate(feats), np.concatenate(labels)

    def run_epoch(self, epoch: int) -> EpochStats:
        c = self.cfg
        t0 = time.perf_counter()
        indices, plan = self._epoch_indices(epoch)
        lr_scale = plan.lr_scale if plan is not None else 1.0
        lr = float(c.lr(epoch)) * lr_scale
        fwd = bwd = 0
        losses = []
        for idx, batch in self.pipeline.batches(indices):
            weight = None
            if c.strategy == "sb":
                # forward-only pass for selection, then masked backward
                lv, _, _ = self._eval_step(self.params, batch)
                keep = self.sb.select(np.asarray(lv))
                weight = jnp.asarray(keep * (len(keep) / max(keep.sum(), 1.0)),
                                     jnp.float32)
                fwd += len(idx)
                bwd += int(keep.sum())
            elif c.strategy == "gradmatch":
                weight = jnp.asarray(self.sampler.weights[idx], jnp.float32)
                fwd += len(idx)
                bwd += len(idx)
            else:
                fwd += len(idx)
                bwd += len(idx)
            b = dict(batch)
            if weight is not None:
                b["weight"] = weight
            if c.strategy in ("iswr", "infobatch"):
                b["weight"] = jnp.asarray(self.sampler.sample_weights(idx))
            self.params, self.opt_state, self.ef_state, scalar, metrics = (
                self._train_step(self.params, self.opt_state, self.ef_state,
                                 b, lr))
            losses.append(float(scalar))
            if self.sampler is not None and hasattr(self.sampler, "observe"):
                lv, pa, pc = metrics
                self.sampler.observe(idx, lv, pa, pc, epoch)
        # KAKURENBO step D: forward-only refresh of the hidden list.
        if plan is not None and len(plan.hidden_indices):
            def fwd_fn(idx):
                return self._eval_step(self.params, self.dataset.get(idx))
            n_ref = self.sampler.refresh_hidden(plan, fwd_fn, c.batch_size)
            fwd += n_ref
        acc = self.evaluate() if (self.test_dataset is not None
                                  and epoch % c.eval_every == 0) else float("nan")
        stats = EpochStats(
            epoch=epoch,
            train_loss=float(np.mean(losses)) if losses else float("nan"),
            test_acc=acc,
            hidden_fraction=plan.hidden_fraction if plan is not None else 0.0,
            fwd_samples=fwd, bwd_samples=bwd, lr=lr,
            wall_time=time.perf_counter() - t0)
        self.history.append(stats)
        self.epoch = epoch + 1
        if (c.checkpoint_dir and c.checkpoint_every
                and (epoch + 1) % c.checkpoint_every == 0):
            self.save_checkpoint()
        return stats

    def run(self, epochs: int | None = None,
            fail_at_epoch: int | None = None) -> list[EpochStats]:
        """Run remaining epochs; ``fail_at_epoch`` injects a simulated crash
        (raises) for the fault-tolerance tests."""
        total = epochs or self.cfg.epochs
        while self.epoch < total:
            if fail_at_epoch is not None and self.epoch == fail_at_epoch:
                raise RuntimeError(f"injected failure at epoch {self.epoch}")
            self.run_epoch(self.epoch)
        return self.history

    # ------------------------------------------------------------------ eval

    def evaluate(self) -> float:
        ds = self.test_dataset
        correct = total = 0
        for idx, batch in Pipeline(ds.get, self.cfg.batch_size).batches(
                np.arange(ds.num_samples)):
            _, pa, _ = self._eval_step(self.params, batch)
            correct += int(np.sum(np.asarray(pa)))
            total += len(idx)
        return correct / max(total, 1)

    # ------------------------------------------------------------------ fault tolerance

    def _ckpt_tree(self):
        tree = {"params": self.params, "opt_state": self.opt_state}
        if self.sampler is not None and hasattr(self.sampler, "state"):
            tree["sampler_state"] = self.sampler.state
        return tree

    def save_checkpoint(self) -> str | None:
        if not self.cfg.checkpoint_dir:
            return None
        # Host RNG states (epoch shuffles / with-replacement draws) must be
        # checkpointed too — without them a restart re-shuffles differently
        # and the resumed trajectory silently diverges from the uninterrupted
        # one (caught by test_checkpoint_restart_bit_exact).
        meta = {"epoch": self.epoch,
                "shuffle_rng": self._shuffle_rng.bit_generator.state}
        if self.sampler is not None and hasattr(self.sampler, "_rng"):
            meta["sampler_rng"] = self.sampler._rng.bit_generator.state
        if self.sb is not None:
            meta["sb_rng"] = self.sb._rng.bit_generator.state
        return ckpt.save(self.cfg.checkpoint_dir, self.epoch,
                         self._ckpt_tree(), metadata=meta)

    def restore_latest(self) -> bool:
        if not self.cfg.checkpoint_dir:
            return False
        res = ckpt.restore_latest(self.cfg.checkpoint_dir, self._ckpt_tree())
        if res is None:
            return False
        tree, meta, step = res
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        if "sampler_state" in tree and self.sampler is not None:
            self.sampler.state = jax.tree.map(jnp.asarray,
                                              tree["sampler_state"])
        self.epoch = meta["epoch"]
        if "shuffle_rng" in meta:
            self._shuffle_rng.bit_generator.state = meta["shuffle_rng"]
        if "sampler_rng" in meta and hasattr(self.sampler, "_rng"):
            self.sampler._rng.bit_generator.state = meta["sampler_rng"]
        if "sb_rng" in meta and self.sb is not None:
            self.sb._rng.bit_generator.state = meta["sb_rng"]
        return True
