"""Fault tolerance & elasticity utilities.

Three concerns at 1000+-node scale, each with a concrete mechanism here:

1. **Node failure → checkpoint/restart.** ``repro.checkpoint`` provides
   atomic, CRC-checked, async checkpoints; ``Trainer.restore_latest`` resumes
   bit-exact (params, optimizer, sampler state incl. KAKURENBO's per-sample
   loss/PA/PC — losing it would silently disable hiding for an epoch).
   ``run_with_restarts`` below is the supervisor loop a cluster agent runs.

2. **Elastic rescaling.** All sampler state is *global* (N-sized arrays);
   workers own deterministic index slices (``data.pipeline.worker_slice``).
   ``rescale_plan`` recomputes every worker's view for a new world size from
   the same epoch permutation — no state migration, resume is bit-exact.

3. **Straggler mitigation.** ``StragglerMonitor`` tracks per-step EMA
   latency; a worker whose latency exceeds ``threshold`` x median is flagged
   and ``rebalance`` shifts a fraction of its per-epoch samples to the
   fastest workers (KAKURENBO composes naturally: hidden-set shrinkage is
   uniform across shards, so re-slicing the visible list is safe).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.data.pipeline import worker_slice


def run_with_restarts(make_trainer: Callable[[], "object"], total_epochs: int,
                      max_restarts: int = 3) -> tuple[object, int]:
    """Supervisor: (re)build the trainer, resume from the latest checkpoint,
    run; on crash, restart. Returns (trainer, restarts_used)."""
    restarts = 0
    while True:
        trainer = make_trainer()
        trainer.restore_latest()
        try:
            trainer.run(total_epochs)
            return trainer, restarts
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise


@dataclasses.dataclass
class RescalePlan:
    world_size: int
    per_worker: list[np.ndarray]


def rescale_plan(epoch_indices: np.ndarray, new_world_size: int,
                 batch_per_worker: int) -> RescalePlan:
    """Deterministic re-slicing of an epoch's index list for a new world size."""
    views = [worker_slice(epoch_indices, new_world_size, r, batch_per_worker)
             for r in range(new_world_size)]
    return RescalePlan(new_world_size, views)


class StragglerMonitor:
    def __init__(self, world_size: int, ema: float = 0.9,
                 threshold: float = 1.5):
        self.lat = np.zeros(world_size)
        self.ema = ema
        self.threshold = threshold

    def record(self, rank: int, step_time: float) -> None:
        a = self.ema
        self.lat[rank] = (a * self.lat[rank] + (1 - a) * step_time
                          if self.lat[rank] > 0 else step_time)

    def stragglers(self) -> np.ndarray:
        med = np.median(self.lat[self.lat > 0]) if (self.lat > 0).any() else 0.0
        if med == 0.0:
            return np.zeros(len(self.lat), bool)
        return self.lat > self.threshold * med

    def rebalance(self, per_worker: list[np.ndarray],
                  shed_fraction: float = 0.25) -> list[np.ndarray]:
        """Move a fraction of each straggler's remaining samples to the
        fastest workers (work stealing at epoch granularity)."""
        flags = self.stragglers()
        if not flags.any():
            return per_worker
        out = [w.copy() for w in per_worker]
        order = np.argsort(self.lat)           # fastest first
        fast = [r for r in order if not flags[r]]
        if not fast:
            return per_worker
        fi = 0
        for r in np.nonzero(flags)[0]:
            k = int(len(out[r]) * shed_fraction)
            if k == 0:
                continue
            moved, out[r] = out[r][-k:], out[r][:-k]
            tgt = fast[fi % len(fast)]
            out[tgt] = np.concatenate([out[tgt], moved])
            fi += 1
        return out
