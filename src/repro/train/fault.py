"""Fault tolerance & elasticity: the supervisor layer above the Trainer.

Three concerns at 1000+-node scale, each with a concrete mechanism here,
all exercised end-to-end by the chaos harness (``train/chaos.py`` +
``tests/test_chaos.py``; see ``docs/fault_tolerance.md``):

1. **Node failure → checkpoint/restart.** ``repro.checkpoint`` provides
   atomic, CRC-checked checkpoints with retry-on-save and a corrupt-dir
   fallback chain; ``Trainer.restore_latest`` resumes bit-exact (params,
   optimizer, sampler state incl. KAKURENBO's per-sample loss/PA/PC —
   losing it would silently disable hiding for an epoch).
   ``run_with_restarts`` below is the supervisor loop a cluster agent
   runs: it *classifies* failures (``classify_failure`` — transient
   XLA/OS/data/checkpoint errors restart, programming bugs don't), backs
   off exponentially between attempts, enforces a restart budget over a
   sliding window, and logs every decision.  In-step numeric faults are
   the Trainer's own guard's job (``train/guard.py``); its
   ``NonFiniteError`` escalation is a ``RuntimeError`` precisely so it
   lands in the restartable class here.

2. **Elastic rescaling.** All sampler state is *global* (N-sized arrays);
   workers own deterministic index slices (``data.pipeline.worker_slice``).
   ``rescale_plan`` recomputes every worker's view for a new world size from
   the same epoch permutation — no state migration, resume is bit-exact.

3. **Straggler mitigation.** ``StragglerMonitor`` tracks per-worker EMA
   latency; a worker whose latency exceeds ``threshold`` x median is flagged
   and ``rebalance`` shifts a fraction of its per-epoch samples to the
   fastest workers (KAKURENBO composes naturally: hidden-set shrinkage is
   uniform across shards, so re-slicing the visible list is safe).  The
   monitor is wired into the Trainer's epoch loop
   (``TrainConfig.straggler_mitigation``): epoch latencies — measured, or
   injected through ``Trainer.shard_latency_fn`` by tests and the chaos
   harness — feed ``record_epoch``, and a flagged epoch re-slices the next
   plan through ``rescale_plan`` + ``rebalance``.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

import numpy as np

from repro.data.pipeline import worker_slice

logger = logging.getLogger("repro.fault")

#: Failure classes a supervisor restart can plausibly cure: I/O and OS
#: faults (disk, network filesystems), runtime faults (XLA's
#: ``XlaRuntimeError`` subclasses RuntimeError — device OOM, preemption —
#: as do the chaos injectors and the numeric guard's ``NonFiniteError``),
#: data/checkpoint decode errors, and torn streams.
RESTARTABLE_EXCEPTIONS: tuple[type[BaseException], ...] = (
    OSError, RuntimeError, ValueError, EOFError, ConnectionError)

#: Programming bugs: restarting replays the same crash deterministically
#: and burns the restart budget hiding the stack trace.  Checked *before*
#: the restartable classes so e.g. KeyError (a LookupError, not a
#: ValueError) fails fast.
FATAL_EXCEPTIONS: tuple[type[BaseException], ...] = (
    TypeError, AttributeError, LookupError, NameError, AssertionError,
    NotImplementedError, ImportError, SyntaxError)


def classify_failure(exc: BaseException) -> str:
    """``"restartable"`` or ``"fatal"`` for a trainer crash.

    The default policy of ``run_with_restarts``: transient hardware/IO/data
    faults restart, programming bugs propagate immediately.  Unknown
    exception types are fatal — restarting on an unclassified failure is
    how supervisors turn one bug into ``max_restarts`` identical crashes.
    """
    if isinstance(exc, FATAL_EXCEPTIONS):
        return "fatal"
    if isinstance(exc, RESTARTABLE_EXCEPTIONS):
        return "restartable"
    return "fatal"


def run_with_restarts(
    make_trainer: Callable[[], "object"],
    total_epochs: int,
    max_restarts: int = 3,
    *,
    backoff_base: float = 0.5,
    backoff_factor: float = 2.0,
    backoff_max: float = 30.0,
    restart_window: float | None = None,
    classify: Callable[[BaseException], str] = classify_failure,
    sleep_fn: Callable[[float], None] = time.sleep,
    clock: Callable[[], float] = time.monotonic,
    on_restart: Callable[[int, BaseException], None] | None = None,
) -> tuple[object, int]:
    """Supervisor: (re)build the trainer, resume from the latest checkpoint,
    run; on a *restartable* crash, back off and restart.

    Returns ``(trainer, restarts_used)``.

    - ``classify`` decides restartable vs fatal (``classify_failure`` by
      default); fatal failures re-raise immediately.
    - Backoff between attempts is ``backoff_base * backoff_factor**k``
      (capped at ``backoff_max``) where ``k`` counts consecutive restarts
      *without progress* — a crash after the trainer advanced at least one
      epoch resets the backoff, so a long healthy run isn't punished for
      its history.  ``backoff_base=0`` disables sleeping.
    - ``restart_window`` (seconds) makes the budget a sliding window: only
      restarts within the last window count against ``max_restarts``.
      ``None`` counts all restarts ever (the legacy budget).
    - ``sleep_fn``/``clock`` are injectable for tests; ``on_restart(n,
      exc)`` is a hook for external telemetry.
    """
    restarts = 0
    restart_times: list[float] = []
    stagnant = 0   # consecutive restarts without epoch progress
    while True:
        trainer = make_trainer()
        trainer.restore_latest()
        start_epoch = int(getattr(trainer, "epoch", 0))
        try:
            trainer.run(total_epochs)
            if restarts:
                logger.info("run completed after %d restart(s)", restarts)
            return trainer, restarts
        except Exception as e:  # noqa: BLE001 — classified below
            kind = classify(e)
            at_epoch = int(getattr(trainer, "epoch", start_epoch))
            if kind != "restartable":
                logger.error(
                    "fatal failure at epoch %d (%s: %s) — not restarting",
                    at_epoch, type(e).__name__, e)
                raise
            restarts += 1
            now = clock()
            restart_times.append(now)
            if restart_window is not None:
                restart_times = [t for t in restart_times
                                 if now - t <= restart_window]
                budget_used = len(restart_times)
            else:
                budget_used = restarts
            stagnant = 0 if at_epoch > start_epoch else stagnant + 1
            if budget_used > max_restarts:
                logger.error(
                    "restart budget exhausted (%d restart(s)%s) after "
                    "failure at epoch %d (%s: %s)", budget_used,
                    "" if restart_window is None
                    else f" within {restart_window:g}s", at_epoch,
                    type(e).__name__, e)
                raise
            delay = (min(backoff_base * backoff_factor ** (stagnant - 1),
                         backoff_max) if backoff_base > 0 and stagnant
                     else 0.0)
            logger.warning(
                "restartable failure at epoch %d (%s: %s) — restart %d/%d "
                "(window use %d, backoff %.2fs, progress=%s)", at_epoch,
                type(e).__name__, e, restarts, max_restarts, budget_used,
                delay, at_epoch > start_epoch)
            if on_restart is not None:
                on_restart(restarts, e)
            if delay:
                sleep_fn(delay)


@dataclasses.dataclass
class RescalePlan:
    world_size: int
    per_worker: list[np.ndarray]


def rescale_plan(epoch_indices: np.ndarray, new_world_size: int,
                 batch_per_worker: int) -> RescalePlan:
    """Deterministic re-slicing of an epoch's index list for a new world size."""
    views = [worker_slice(epoch_indices, new_world_size, r, batch_per_worker)
             for r in range(new_world_size)]
    return RescalePlan(new_world_size, views)


class StragglerMonitor:
    def __init__(self, world_size: int, ema: float = 0.9,
                 threshold: float = 1.5):
        self.lat = np.zeros(world_size)
        self.ema = ema
        self.threshold = threshold

    @property
    def world_size(self) -> int:
        return len(self.lat)

    def record(self, rank: int, step_time: float) -> None:
        a = self.ema
        self.lat[rank] = (a * self.lat[rank] + (1 - a) * step_time
                          if self.lat[rank] > 0 else step_time)

    def record_epoch(self, latencies) -> None:
        """Record one epoch's per-worker latencies (len == world_size)."""
        if len(latencies) != len(self.lat):
            raise ValueError(
                f"got {len(latencies)} latencies for world_size "
                f"{len(self.lat)}")
        for rank, t in enumerate(latencies):
            self.record(rank, float(t))

    def stragglers(self) -> np.ndarray:
        med = np.median(self.lat[self.lat > 0]) if (self.lat > 0).any() else 0.0
        if med == 0.0:
            return np.zeros(len(self.lat), bool)
        return self.lat > self.threshold * med

    def rebalance(self, per_worker: list[np.ndarray],
                  shed_fraction: float = 0.25) -> list[np.ndarray]:
        """Move a fraction of each straggler's remaining samples to the
        fastest workers (work stealing at epoch granularity)."""
        flags = self.stragglers()
        if not flags.any():
            return per_worker
        out = [w.copy() for w in per_worker]
        order = np.argsort(self.lat)           # fastest first
        fast = [r for r in order if not flags[r]]
        if not fast:
            return per_worker
        fi = 0
        for r in np.nonzero(flags)[0]:
            k = int(len(out[r]) * shed_fraction)
            if k == 0:
                continue
            moved, out[r] = out[r][-k:], out[r][:-k]
            tgt = fast[fi % len(fast)]
            out[tgt] = np.concatenate([out[tgt], moved])
            fi += 1
        return out
