"""Deterministic fault injection for the training stack (chaos harness).

Every injector here is seeded/counted — no wall-clock, no real randomness —
so a chaos test is exactly as reproducible as the trainer it attacks, and
"recovery is bit-exact" is a meaningful assertion.  The injectors cover the
failure modes the resilience layer claims to handle
(``docs/fault_tolerance.md``):

==============================  ===========================================
injector                        fault it models
==============================  ===========================================
:class:`CrashAtStep`            process death mid-epoch (preemption, OOM
                                kill) — raises :class:`ChaosError` at a
                                global train-step boundary, under either
                                epoch engine
:func:`poison_samples`          corrupt input records — NaN pixels for
                                chosen sample ids, exercising the numeric
                                guard + score quarantine
:func:`corrupt_checkpoint_leaf` bit-rot on stored checkpoints — seeded
                                byte flips in a committed leaf, exercising
                                CRC detection + the restore fallback chain
:func:`failing_leaf_writes`     failing disks during save — patches the
                                checkpoint writer's single-leaf seam,
                                exercising save retry + async failure
                                propagation
:class:`SlowShard`              a straggling worker — injectable per-epoch
                                latency vector for
                                ``Trainer.shard_latency_fn``
==============================  ===========================================

``ChaosError`` subclasses ``RuntimeError`` so ``fault.classify_failure``
treats an injected crash exactly like a real preemption: restartable.
Used by ``tests/test_chaos.py`` across the full strategy registry × both
engines.
"""
from __future__ import annotations

import contextlib
import os
from typing import Any, Sequence

import numpy as np

from repro.checkpoint import checkpoint as ckpt


class ChaosError(RuntimeError):
    """An injected failure.  RuntimeError subclass → restartable."""


class CrashAtStep:
    """Crash the trainer at global train step ``step`` (0-based).

    ``install(trainer)`` wraps the dispatch seam of whichever engine the
    trainer runs: the host loop's per-batch jitted step (crash *before*
    dispatching step ``step`` — params/opt/strategy state are at the step
    boundary, matching a preemption between steps), or the scanned engine's
    block dispatch (crash before the block that would cover step ``step`` —
    scan-block granularity, the engine's own crash contract).  Counting is
    cumulative across epochs; the bomb fires once.
    """

    def __init__(self, step: int):
        self.step = int(step)
        self.steps_done = 0
        self.fired = False

    def install(self, trainer) -> "CrashAtStep":
        if trainer.engine.name == "scan":
            self._install_scan(trainer.engine)
        else:
            self._install_host(trainer)
        return self

    def _install_host(self, trainer) -> None:
        inner = trainer._train_step

        def bomb(*args, **kwargs):
            if not self.fired and self.steps_done >= self.step:
                self.fired = True
                raise ChaosError(
                    f"injected crash before train step {self.steps_done}")
            self.steps_done += 1
            return inner(*args, **kwargs)

        trainer._train_step = bomb

    def _install_scan(self, engine) -> None:
        if engine._block is None:
            engine._build_block()
        inner = engine._block

        def bomb(carry, xs, epoch, lr):
            import jax
            size = jax.tree.leaves(xs)[0].shape[0]
            if not self.fired and self.steps_done + size > self.step:
                self.fired = True
                raise ChaosError(
                    f"injected crash before the scan block covering step "
                    f"{self.step} (at step {self.steps_done})")
            self.steps_done += size
            return inner(carry, xs, epoch, lr)

        engine._block = bomb


class PoisonedDataset:
    """Dataset wrapper that NaNs the float features of chosen sample ids.

    Poison is applied in both access paths — per-batch ``get`` (host
    engine) and bulk ``arrays`` (scanned engine's device-resident data) —
    so either engine sees identical corruption.  Integer arrays (labels)
    are left intact: the fault modeled is corrupt *features*, and NaN has
    no integer representation.
    """

    def __init__(self, base, sample_ids: Sequence[int]):
        self.base = base
        self.ids = np.asarray(sorted(int(i) for i in sample_ids))

    @property
    def num_samples(self) -> int:
        return self.base.num_samples

    def __getattr__(self, name: str) -> Any:
        return getattr(self.base, name)

    def _poison(self, batch: dict, mask: np.ndarray) -> dict:
        out = dict(batch)
        for k, v in out.items():
            arr = np.asarray(v)
            if np.issubdtype(arr.dtype, np.floating) and mask.any():
                arr = np.array(arr)
                arr[mask] = np.nan
                out[k] = arr
        return out

    def get(self, indices) -> dict:
        idx = np.asarray(indices)
        return self._poison(self.base.get(indices), np.isin(idx, self.ids))

    def arrays(self) -> dict:
        full = self.base.arrays()
        mask = np.zeros(self.num_samples, bool)
        mask[self.ids] = True
        return self._poison(dict(full), mask)


def poison_samples(dataset, sample_ids: Sequence[int]) -> PoisonedDataset:
    """NaN the features of ``sample_ids`` in every access path."""
    return PoisonedDataset(dataset, sample_ids)


def corrupt_checkpoint_leaf(directory: str, step: int | None = None,
                            leaf: int = 0, seed: int = 0,
                            num_flips: int = 8) -> str:
    """Flip bytes in a committed checkpoint leaf (seeded, in place).

    ``step=None`` targets the newest committed step.  The COMMITTED marker
    and manifest are untouched — the dir still *looks* valid, which is the
    point: only the CRC check can catch it.  Returns the corrupted file's
    path.
    """
    if step is None:
        step = ckpt.latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:010d}",
                        f"leaf_{leaf:05d}.npy")
    data = bytearray(open(path, "rb").read())
    rng = np.random.default_rng(seed)
    # Flip payload bytes only (skip the ~128-byte npy header: a garbled
    # header is an unreadable leaf, a garbled payload is silent bit-rot —
    # the CRC must catch the latter, the nastier case).
    lo = min(128, max(len(data) - 1, 0))
    for pos in rng.integers(lo, len(data), size=num_flips):
        data[pos] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(data))
    return path


@contextlib.contextmanager
def failing_leaf_writes(fail: int = 1, exc: type[Exception] = OSError,
                        message: str = "injected I/O failure"):
    """Patch the checkpoint writer's single-leaf seam to fail.

    The first ``fail`` leaf writes raise ``exc``; later writes go through
    (``fail=-1`` fails forever).  Models a flaky (or dead) disk under
    ``checkpoint.save`` — pair with ``save``'s retry loop or
    ``save_async``'s handle to assert the failure surfaces.
    """
    inner = ckpt._write_leaf
    calls = {"n": 0}

    def flaky(path, arr):
        calls["n"] += 1
        if fail < 0 or calls["n"] <= fail:
            raise exc(message)
        inner(path, arr)

    ckpt._write_leaf = flaky
    try:
        yield calls
    finally:
        ckpt._write_leaf = inner


class SlowShard:
    """Per-epoch latency vector with one straggling worker.

    ``Trainer.shard_latency_fn`` drop-in: every worker reports ``base``
    except ``rank``, which reports ``base * factor`` from epoch
    ``from_epoch`` on.  Deterministic — the straggler flags on exactly the
    same epoch every run.
    """

    def __init__(self, world_size: int, rank: int, factor: float = 4.0,
                 base: float = 1.0, from_epoch: int = 0):
        self.world_size = world_size
        self.rank = rank
        self.factor = factor
        self.base = base
        self.from_epoch = from_epoch

    def __call__(self, epoch: int) -> list[float]:
        lat = [self.base] * self.world_size
        if epoch >= self.from_epoch:
            lat[self.rank] = self.base * self.factor
        return lat
