"""In-step numeric guards: detect and contain non-finite loss/grads.

KAKURENBO's hiding decisions are driven entirely by per-sample loss history
(paper Sec. 3.4), which makes numeric faults *selection* faults, not just
optimisation faults: a single NaN loss scattered into ``SampleState`` reads
as "infinitely important" forever — the sample can never be hidden, the
histogram thresholds of ``core/planops.py`` stretch to the NaN span, and the
epoch plan silently stops being the paper's.  Importance-sampling baselines
are known to destabilise under loss outliers (Katharopoulos & Fleuret 2018;
Jiang et al. 2019), so guarded scoring is a correctness feature here.

The guard runs *inside* the jitted train step (``Trainer._step_core``, both
the single-device and the mesh-sharded variant, under either epoch engine):

- **detection** — ``all_finite(scalar, grads)`` reduces the step loss and
  every gradient leaf to one device boolean;
- **containment** (``guard_policy="skip_update"``) — a non-finite step
  zeroes the gradients *before* error-feedback compression (so the EF
  residual is not poisoned) and holds params / optimizer state / EF at
  their pre-step values via an elementwise select, i.e. the step becomes a
  no-op for the trajectory;
- **score quarantine** — per-sample observations with non-finite loss or
  confidence are dropped from the fused observe scatter
  (``core/state.py::scatter_observations(valid=...)``): the sample keeps
  its previous (finite) loss/PA/PC *and* its previous ``seen`` epoch, so
  the next epoch plan is finite and bit-reproducible;
- **accounting** — ``GuardState`` carries three device ``i32`` counters
  (total non-finite steps, consecutive non-finite steps, quarantined
  observations) through the epoch exactly like the strategy's device state,
  so the host syncs stay at 1/epoch: the engines fetch the counters in the
  same ``device_get`` that materialises the per-step losses.

``guard_abort_after=k`` layers an abort policy on top: the trainer checks
the consecutive counter at the epoch boundary (the only host sync) and
raises ``NonFiniteError`` once ``k`` consecutive steps were non-finite —
the supervisor (``train/fault.py::run_with_restarts``) classifies that as
restartable, which is the right default for transient hardware faults.

With ``guard_policy="off"`` (the default) none of this traces into the
step: the compiled computation is byte-identical to the unguarded trainer.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

#: Valid ``TrainConfig.guard_policy`` values.
GUARD_POLICIES = ("off", "skip_update")


class NonFiniteError(RuntimeError):
    """Raised by the trainer's epoch-boundary check when
    ``guard_abort_after`` consecutive train steps produced a non-finite
    loss or gradient.  A ``RuntimeError`` subclass on purpose: the
    supervisor classifies it as restartable (transient-fault default)."""


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GuardState:
    """Device-resident guard counters threaded through the epoch.

    Rides next to the strategy's device state in the step signature (and in
    the scanned engine's ``TrainCarry``), so guarding costs zero extra host
    round trips.  All three are ``i32`` device scalars; under the mesh
    trainer they are replicated (they summarise the *global* step).

    Attributes:
      nonfinite_steps: total steps whose loss/grads were non-finite (and —
        under ``skip_update`` — whose update was therefore skipped).
      consecutive: current run of consecutive non-finite steps (reset by
        any finite step); the ``guard_abort_after`` trigger.
      quarantined: total per-sample observations dropped from the fused
        observe scatter because their loss/confidence was non-finite.
    """

    nonfinite_steps: jax.Array
    consecutive: jax.Array
    quarantined: jax.Array


def init_guard_state() -> GuardState:
    return GuardState(
        nonfinite_steps=jnp.int32(0),
        consecutive=jnp.int32(0),
        quarantined=jnp.int32(0),
    )


def all_finite(scalar: jax.Array, grads) -> jax.Array:
    """One device boolean: the step loss and every gradient leaf are finite.

    The O(params) ``isfinite`` reduction is the guard's whole step cost —
    benchmarked (guard-on vs guard-off) by ``benchmarks/step_throughput.py
    --guard`` into ``results/BENCH_steps.json`` with a <3% budget.
    """
    ok = jnp.isfinite(scalar)
    for g in jax.tree.leaves(grads):
        ok = ok & jnp.all(jnp.isfinite(g))
    return ok


def zero_if(bad: jax.Array, grads):
    """Zero every gradient leaf when ``bad`` (a device bool scalar).

    Applied *before* error-feedback compression so a poisoned gradient
    never enters the EF residual.
    """
    return jax.tree.map(lambda g: jnp.where(bad, jnp.zeros_like(g), g), grads)


def select(ok: jax.Array, new, old):
    """Elementwise pytree select: ``new`` where ``ok`` else ``old``.

    The ``skip_update`` containment: with ``ok=False`` the params /
    optimizer state / EF residual hold their pre-step values bit-exactly
    (``where`` never propagates the discarded branch's NaNs).
    """
    return jax.tree.map(lambda n, o: jnp.where(ok, n, o), new, old)


def observation_valid(loss: jax.Array, pc: jax.Array) -> jax.Array:
    """(B,) mask of per-sample observations safe to scatter into
    ``SampleState``: finite loss and finite confidence."""
    return jnp.isfinite(loss) & jnp.isfinite(pc)


def update_counters(gstate: GuardState, ok: jax.Array,
                    quarantined: jax.Array) -> GuardState:
    """Advance the counters for one step (all device-side)."""
    bad = (~ok).astype(jnp.int32)
    return GuardState(
        nonfinite_steps=gstate.nonfinite_steps + bad,
        consecutive=jnp.where(ok, jnp.int32(0), gstate.consecutive + 1),
        quarantined=gstate.quarantined + quarantined.astype(jnp.int32),
    )
