from repro.optim.optimizers import (  # noqa: F401
    Optimizer, make_optimizer, sgd, adamw, rmsprop, adafactor,
)
