"""Optimizers in pure JAX: SGD(+momentum/Nesterov), AdamW, RMSProp, Adafactor.

The paper trains with SGD-momentum (ResNet/WRN/DeepCAM), RMSProp
(EfficientNet) and AdamW (DeiT) — all provided.  Adafactor (factored second
moment, no momentum) is used for the 1T-param kimi-k2 config where full Adam
state would not fit HBM (DESIGN.md Sec. 5).

API: ``opt = make_optimizer(name, **hp); state = opt.init(params);
params, state = opt.update(grads, state, params, lr)``.  States are pytrees
mirroring params, so pjit shards them exactly like the parameters (ZeRO).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    name: str = ""


def _tmap(f, *trees):
    return jax.tree.map(f, *trees)


def sgd(momentum: float = 0.0, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return _tmap(jnp.zeros_like, params)

    def update(grads, state, params, lr):
        if weight_decay:
            grads = _tmap(lambda g, p: g + weight_decay * p, grads, params)
        if momentum == 0.0:
            return _tmap(lambda p, g: p - lr * g, params, grads), state
        new_m = _tmap(lambda m, g: momentum * m + g, state, grads)
        if nesterov:
            step = _tmap(lambda m, g: momentum * m + g, new_m, grads)
        else:
            step = new_m
        return _tmap(lambda p, s: p - lr * s, params, step), new_m

    return Optimizer(init, update, "sgd")


def adamw(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01, state_dtype=None) -> Optimizer:
    def init(params):
        z = (lambda p: jnp.zeros(p.shape, state_dtype or p.dtype))
        return {"m": _tmap(z, params), "v": _tmap(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        m = _tmap(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype),
                  state["m"], grads)
        v = _tmap(lambda v, g: b2 * v + (1 - b2) * jnp.square(g).astype(v.dtype),
                  state["v"], grads)

        def step(p, m_, v_):
            mh = m_.astype(jnp.float32) / bc1
            vh = v_.astype(jnp.float32) / bc2
            upd = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        return _tmap(step, params, m, v), {"m": m, "v": v, "t": t}

    return Optimizer(init, update, "adamw")


def rmsprop(decay: float = 0.9, momentum: float = 0.9, eps: float = 1e-8,
            weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"v": _tmap(jnp.zeros_like, params),
                "m": _tmap(jnp.zeros_like, params)}

    def update(grads, state, params, lr):
        if weight_decay:
            grads = _tmap(lambda g, p: g + weight_decay * p, grads, params)
        v = _tmap(lambda v, g: decay * v + (1 - decay) * jnp.square(g),
                  state["v"], grads)
        m = _tmap(lambda m, g, v_: momentum * m + g / (jnp.sqrt(v_) + eps),
                  state["m"], grads, v)
        return _tmap(lambda p, m_: p - lr * m_, params, m), {"v": v, "m": m}

    return Optimizer(init, update, "rmsprop")


def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    """Factored second moment: O(rows+cols) state for matrices (1T-param HBM
    budget); vectors fall back to a full second moment."""

    def init(params):
        def one(p):
            if p.ndim >= 2:
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"s": _tmap(one, params), "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        beta = 1.0 - t.astype(jnp.float32) ** -0.8

        def one(p, g, s):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if p.ndim >= 2:
                r = beta * s["r"] + (1 - beta) * jnp.mean(g2, axis=-1)
                c = beta * s["c"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    r[..., :, None] * c[..., None, :]
                    / jnp.maximum(jnp.mean(r, axis=-1, keepdims=True)[..., None],
                                  eps))
                upd = gf / jnp.maximum(denom, eps)
                new_s = {"r": r, "c": c}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                upd = gf / (jnp.sqrt(v) + eps)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + eps)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), new_s

        flat_p, td = jax.tree.flatten(params)
        flat_g = jax.tree.flatten(grads)[0]
        flat_s = jax.tree.flatten(
            state["s"], is_leaf=lambda x: isinstance(x, dict) and (
                "r" in x or "v" in x))[0]
        out = [one(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = jax.tree.unflatten(td, [o[0] for o in out])
        new_s = jax.tree.unflatten(
            jax.tree.structure(state["s"], is_leaf=lambda x: isinstance(x, dict)
                               and ("r" in x or "v" in x)),
            [o[1] for o in out])
        return new_params, {"s": new_s, "t": t}

    return Optimizer(init, update, "adafactor")


def make_optimizer(name: str, **hp) -> Optimizer:
    return {"sgd": sgd, "adamw": adamw, "rmsprop": rmsprop,
            "adafactor": adafactor}[name](**hp)
