import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (SPMD partitioning succeeds),
  * the step fits (memory_analysis),
  * and extracts FLOPs / bytes / collective volume for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]

Results are one JSON per cell (resumable: existing files are skipped).
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, shape_applicable, tokens_per_step
from repro.configs.registry import ARCHS, get_arch
from repro.launch import hlo_analysis
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.roofline_model import analytic_hbm_bytes
from repro.launch.train import (abstract_train_state, build_ctx,
                                make_train_step, optimizer_for, shardings_for)
from repro.models.common import scan_unroll
from repro.models.model import Model


def _analyze(lowered, compiled, chips, model_flops, cfg=None, shape=None):
    # cost_analysis runs on the per-device module post-SPMD: flops/bytes are
    # PER DEVICE (verified empirically; see EXPERIMENTS.md §Dry-run).
    cost = compiled.cost_analysis() or {}
    flops_pd = float(cost.get("flops", 0.0))
    hbm_xla_pd = float(cost.get("bytes accessed", 0.0))
    flops = flops_pd * chips
    # XLA-CPU "bytes accessed" is pre-fusion and >10x pessimistic for TPU;
    # the memory term uses the analytic HBM model (roofline_model.py).
    if cfg is not None and shape is not None:
        hbm = analytic_hbm_bytes(cfg, shape, cfg.optimizer)["total"]
    else:
        hbm = hbm_xla_pd * chips
    coll = hlo_analysis.collective_bytes(compiled.as_text())
    coll_total = sum(v for k, v in coll.items() if k != "count")
    roof = hlo_analysis.Roofline(
        flops=flops, hbm_bytes=hbm, coll_bytes=coll_total, chips=chips,
        peak_flops=PEAK_FLOPS_BF16, hbm_bw=HBM_BW, ici_bw=ICI_BW)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:  # noqa: BLE001 — backend-dependent
        mem["error"] = str(e)
    return {
        "hlo_flops": flops,
        "hlo_bytes": hbm,
        "hlo_bytes_xla": hbm_xla_pd * chips,
        "collective_bytes": coll,
        "collective_bytes_total": coll_total,
        "memory": mem,
        "model_flops": model_flops,
        "useful_flops_ratio": model_flops / flops if flops else None,
        "roofline": roof.as_dict(),
    }


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool = False,
             seq_parallel_kv: bool = False, fsdp: bool | None = None,
             remat: bool = True, dtype=jnp.bfloat16,
             unroll: bool = True, dp_only: bool = False,
             remat_policy: str = "nothing",
             moe_fsdp_mode: str = "gather") -> dict:
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "seq_parallel_kv": seq_parallel_kv,
           "unrolled": unroll, "dp_only": dp_only,
           "remat_policy": remat_policy, "moe_fsdp_mode": moe_fsdp_mode}
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec
    t0 = time.perf_counter()
    # Fully unroll the layer loop so cost_analysis counts every layer
    # (XLA counts a while body once); see models/common.py scan_unroll.
    unroll_n = max(cfg.num_layers, cfg.num_encoder_layers) if unroll else 1
    with scan_unroll(unroll_n):
        rec = _run_cell_inner(rec, cfg, shape, multi_pod, seq_parallel_kv,
                              fsdp, remat, dtype, t0, dp_only, moe_fsdp_mode,
                              remat_policy)
    return rec


def _scale_layers(cfg, n: int):
    """Same-family config with n layers (for per-layer cost extraction)."""
    import dataclasses
    return dataclasses.replace(
        cfg, num_layers=n,
        num_encoder_layers=n if cfg.num_encoder_layers else 0)


def run_cell_extrapolated(arch_name: str, shape_name: str, **kw) -> dict:
    """Roofline via exact linear extrapolation in layer count.

    cost_analysis(L) = outside + L * per_layer for every linear metric
    (flops, bytes, collective payloads).  Compiling fully-unrolled L=2 and
    L=4 variants solves for both terms; the true-L totals follow without the
    (hours-long on 1 CPU core) full-depth unrolled compile.  Validated
    against exact full unrolls for the small archs (EXPERIMENTS.md §Dry-run).
    """
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    base = {"arch": arch_name, "shape": shape_name,
            "mesh": "pod2x16x16" if kw.get("multi_pod") else "pod16x16",
            "kind": shape.kind, "method": "extrapolate_L2_L4"}
    if not ok:
        base.update(status="skip", reason=reason)
        return base
    import repro.configs.registry as reg
    recs = {}
    for n in (2, 4):
        small = _scale_layers(cfg, n)
        key = f"__extrap_{arch_name}_{n}"
        reg.ARCHS[key] = small
        try:
            recs[n] = run_cell(key, shape_name, unroll=True, **kw)
        finally:
            del reg.ARCHS[key]
        if recs[n]["status"] != "ok":
            base.update(status="error",
                        error=f"L={n} probe failed: {recs[n].get('error')}")
            return base
    L = cfg.num_layers

    def extrap(get):
        m2, m4 = get(recs[2]), get(recs[4])
        per_layer = (m4 - m2) / 2.0
        outside = m2 - 2.0 * per_layer
        return max(outside + L * per_layer, 0.0)

    rec = dict(base)
    rec["hlo_flops"] = extrap(lambda r: r["hlo_flops"])
    rec["hlo_bytes"] = analytic_hbm_bytes(cfg, shape, cfg.optimizer)["total"]
    rec["hlo_bytes_xla_extrap"] = extrap(lambda r: r["hlo_bytes_xla"])
    rec["hbm_terms"] = analytic_hbm_bytes(cfg, shape, cfg.optimizer)
    coll = {}
    for kind in recs[2]["collective_bytes"]:
        coll[kind] = extrap(lambda r, k=kind: float(r["collective_bytes"][k]))
    rec["collective_bytes"] = coll
    rec["collective_bytes_total"] = sum(
        v for k, v in coll.items() if k != "count")
    chips = 512 if kw.get("multi_pod") else 256
    model_flops = ((6 if shape.kind == "train" else 2)
                   * cfg.active_param_count() * tokens_per_step(shape))
    roof = hlo_analysis.Roofline(
        flops=rec["hlo_flops"], hbm_bytes=rec["hlo_bytes"],
        coll_bytes=rec["collective_bytes_total"], chips=chips,
        peak_flops=PEAK_FLOPS_BF16, hbm_bw=HBM_BW, ici_bw=ICI_BW)
    rec["model_flops"] = model_flops
    rec["useful_flops_ratio"] = (model_flops / rec["hlo_flops"]
                                 if rec["hlo_flops"] else None)
    rec["roofline"] = roof.as_dict()
    rec["memory"] = recs[4].get("memory", {})
    rec["probe_compile_s"] = [recs[2].get("compile_s"), recs[4].get("compile_s")]
    rec["status"] = "ok"
    return rec


def _run_cell_inner(rec, cfg, shape, multi_pod, seq_parallel_kv, fsdp, remat,
                    dtype, t0, dp_only=False, moe_fsdp_mode="gather",
                    remat_policy="nothing"):
    arch_name = cfg.name
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.size
        ctx = build_ctx(cfg, mesh, fsdp=fsdp, seq_parallel_kv=seq_parallel_kv,
                        remat=remat, dp_only=dp_only,
                        remat_policy=remat_policy,
                        moe_fsdp_mode=moe_fsdp_mode)
        rec["fsdp"] = ctx.fsdp
        model = Model(cfg, ctx)
        in_specs = model.input_shardings(shape, dtype)
        in_shardings = shardings_for(mesh, in_specs)
        inputs = model.input_specs(shape, dtype)

        if shape.kind == "train":
            opt = optimizer_for(cfg)
            params_abs, opt_abs, pspecs, ospecs = abstract_train_state(
                model, opt, dtype)
            step = make_train_step(model, opt)
            dp = ctx.dp_axes
            metr = NamedSharding(mesh, P(dp))
            scalar = NamedSharding(mesh, P())
            jitted = jax.jit(
                step,
                in_shardings=(shardings_for(mesh, pspecs),
                              shardings_for(mesh, ospecs),
                              in_shardings, scalar),
                out_shardings=(shardings_for(mesh, pspecs),
                               shardings_for(mesh, ospecs),
                               scalar, (metr, metr, metr)),
                donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, inputs,
                                   jax.ShapeDtypeStruct((), jnp.float32))
            model_flops = 6 * cfg.active_param_count() * tokens_per_step(shape)
        elif shape.kind == "prefill":
            params_abs = model.abstract_params(dtype)
            pspecs = model.param_specs(dtype)

            def prefill_fn(params, batch):
                return model.prefill(params, batch)

            jitted = jax.jit(
                prefill_fn,
                in_shardings=(shardings_for(mesh, pspecs), in_shardings))
            lowered = jitted.lower(params_abs, inputs)
            model_flops = 2 * cfg.active_param_count() * tokens_per_step(shape)
        else:  # decode
            params_abs = model.abstract_params(dtype)
            pspecs = model.param_specs(dtype)

            def decode_fn(params, token, cache):
                return model.decode_step(params, token, cache)

            jitted = jax.jit(
                decode_fn,
                in_shardings=(shardings_for(mesh, pspecs),
                              in_shardings["token"], in_shardings["cache"]),
                donate_argnums=(2,))
            lowered = jitted.lower(params_abs, inputs["token"],
                                   inputs["cache"])
            model_flops = 2 * cfg.active_param_count() * tokens_per_step(shape)

        rec["lower_s"] = time.perf_counter() - t0
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = time.perf_counter() - t1
        rec.update(_analyze(lowered, compiled, chips, model_flops, cfg, shape))
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — any failure here is a finding
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    rec["total_s"] = time.perf_counter() - t0
    return rec


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--seq-parallel-kv", action="store_true")
    p.add_argument("--dp-only", action="store_true",
                   help="map the model axis to data parallelism (ZeRO-3, "
                        "no TP) — §Perf variant for small archs")
    p.add_argument("--remat-dots", action="store_true",
                   help="remat policy: save dot outputs (recompute only "
                        "elementwise) — §Perf variant for compute-bound train")
    p.add_argument("--moe-partial", action="store_true",
                   help="MoE partial-ff mode (no weight gathers) — §Perf "
                        "variant for MoE decode")
    p.add_argument("--no-fsdp", action="store_true")
    p.add_argument("--rolled", action="store_true",
                   help="keep the layer scan rolled (fast compile; use for "
                        "the 2-mesh coherence pass — roofline numbers then "
                        "undercount the layer loop)")
    p.add_argument("--extrapolate", action="store_true",
                   help="derive true-L roofline terms from unrolled L=2/L=4 "
                        "probe compiles (exact linear extrapolation; avoids "
                        "hours-long full-depth unrolled compiles)")
    p.add_argument("--out", default="results/dryrun")
    p.add_argument("--tag", default="")
    args = p.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    for a, s, mp in cells:
        tag = f"{args.tag}_" if args.tag else ""
        name = f"{tag}{a}_{s}_{'mp' if mp else 'sp'}"
        if args.seq_parallel_kv:
            name += "_spkv"
        if args.dp_only:
            name += "_dponly"
        if args.remat_dots:
            name += "_rematdots"
        if args.moe_partial:
            name += "_moepartial"
        if args.rolled:
            name += "_rolled"
        path = os.path.join(args.out, name + ".json")
        if os.path.exists(path):
            print(f"[skip existing] {name}")
            continue
        print(f"[run] {name}", flush=True)
        kw = dict(multi_pod=mp, seq_parallel_kv=args.seq_parallel_kv,
                  fsdp=False if args.no_fsdp else None,
                  dp_only=args.dp_only,
                  remat_policy="dots" if args.remat_dots else "nothing",
                  moe_fsdp_mode="partial" if args.moe_partial else "gather")
        if args.extrapolate:
            rec = run_cell_extrapolated(a, s, **kw)
        else:
            rec = run_cell(a, s, unroll=not args.rolled, **kw)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" bottleneck={r['bottleneck']}"
                     f" t={r['step_time_s']:.4f}s"
                     f" compile={rec.get('compile_s', 0) or 0:.1f}s")
        elif status == "error":
            extra = " " + rec["error"][:200]
        print(f"  -> {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
