"""Batched serving driver: prefill a prompt batch, decode N tokens.

Runs any registry arch (``--reduced`` for CPU-sized smoke runs); the same
Model API the dry-run lowers for the production mesh.  Reports prefill and
per-token decode latency/throughput.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.models import build_model


def serve(arch: str, *, reduced: bool = True, batch: int = 4,
          prompt_len: int = 32, gen_tokens: int = 16, seed: int = 0,
          greedy: bool = True, verbose: bool = True) -> dict:
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(seed))
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, prompt_len)),
                       jnp.int32)
    pbatch = {"tokens": toks}
    if cfg.family == "encdec":
        pbatch["frames"] = jnp.asarray(
            rng.normal(size=(batch, prompt_len, cfg.encoder_input_dim)),
            jnp.float32)
    if cfg.family == "vlm":
        pbatch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.num_patch_tokens, 1024)), jnp.float32)

    max_len = prompt_len + gen_tokens + cfg.num_patch_tokens

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
    decode = jax.jit(model.decode_step)

    t0 = time.perf_counter()
    logits, cache = prefill(params, pbatch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t1 = time.perf_counter()
    for _ in range(gen_tokens):
        out_tokens.append(np.asarray(tok))
        logits, cache = decode(params, tok, cache)
        tok = (jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
               if greedy else tok)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t1

    gen = np.concatenate(out_tokens, axis=1)
    stats = {
        "arch": cfg.name,
        "prefill_s": t_prefill,
        "decode_per_token_ms": t_decode / gen_tokens * 1e3,
        "decode_tok_per_s": batch * gen_tokens / t_decode,
        "generated": gen,
    }
    if verbose:
        print(f"arch={cfg.name} batch={batch} prompt={prompt_len} "
              f"gen={gen_tokens}")
        print(f"prefill: {t_prefill * 1e3:.1f} ms   "
              f"decode: {stats['decode_per_token_ms']:.1f} ms/tok   "
              f"throughput: {stats['decode_tok_per_s']:.1f} tok/s")
        print("sample tokens:", gen[0][:12].tolist())
    return stats


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--full", action="store_true",
                   help="use the full config (needs a real mesh)")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen-tokens", type=int, default=16)
    args = p.parse_args()
    serve(args.arch, reduced=not args.full, batch=args.batch,
          prompt_len=args.prompt_len, gen_tokens=args.gen_tokens)


if __name__ == "__main__":
    main()
