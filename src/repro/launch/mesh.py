"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  The dry-run process sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; the single-pod mesh then uses the first 256 of those placeholder
devices and the multi-pod mesh all 512.
"""
from __future__ import annotations

import jax

from repro.dist.sharding import ParallelCtx


def make_data_mesh(num_devices: int):
    """Pure data-parallel mesh: ``(num_devices,)`` over the ``("data",)`` axis.

    This is the mesh the host trainer (``train/trainer.py``) runs under when
    ``TrainConfig.mesh_shape`` is set: params/optimizer state replicated,
    batches and ``SampleState`` row-sharded over ``"data"``.  On this CPU
    container the devices are host-simulated
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    """
    devices = jax.devices()[:num_devices]
    if len(devices) < num_devices:
        raise RuntimeError(
            f"data mesh ({num_devices},) needs {num_devices} devices, have "
            f"{len(jax.devices())} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={num_devices}")
    return jax.make_mesh((num_devices,), ("data",), devices=devices)


def data_parallel_ctx(num_devices: int) -> ParallelCtx:
    """ParallelCtx over a fresh ``("data",)`` mesh (trainer + benchmarks)."""
    return ParallelCtx(mesh=make_data_mesh(num_devices))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devices = jax.devices()[:need]
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(jax.devices())} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.make_mesh(shape, axes, devices=devices)


# TPU v5e hardware constants (roofline denominators; see EXPERIMENTS.md).
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
