"""Post-SPMD HLO analysis: collective wire-byte counts + roofline terms.

``compiled.as_text()`` (optimized HLO, collectives already inserted by the
SPMD partitioner) is scanned for all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops.  Each op contributes its **wire bytes
per participant** under ring/torus algorithms:

  all-reduce      2 x operand   (reduce-scatter + all-gather phases)
  all-gather      1 x result    (result = n x operand; each device moves ~n-1
                                 operand-sized chunks ~= result)
  reduce-scatter  1 x operand   (result is operand/n — counting the result
                                 would understate wire traffic n-fold)
  all-to-all      1 x operand
  collective-permute 1 x operand

This is the per-device payload the ICI term divides by link bandwidth.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

# "<result part> = <op>(<operands...>)" — result part may be a tuple.
_LINE_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<kind>" + "|".join(_COLLECTIVES) + r")(?P<suffix>-start|-done)?"
    r"\((?P<operands>[^)]*)\)")


def _shapes_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        nb = _DTYPE_BYTES.get(dtype)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Wire bytes per participating device, per collective kind."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for m in _LINE_RE.finditer(hlo_text):
        kind = m.group("kind")
        # -done ops repeat the -start payload; count each logical op once.
        if m.group("suffix") == "-done":
            continue
        operand_bytes = _shapes_bytes(m.group("operands"))
        result_bytes = _shapes_bytes(m.group("result"))
        if kind == "all-reduce":
            wire = 2 * operand_bytes
        elif kind == "all-gather":
            wire = result_bytes
        else:  # reduce-scatter / all-to-all / collective-permute
            wire = operand_bytes
        out[kind] += wire
        out["count"] += 1
    return out


@dataclasses.dataclass
class Roofline:
    """Three-term roofline (seconds) for one compiled step on one mesh."""

    flops: float               # HLO flops (global)
    hbm_bytes: float           # analytic HBM bytes (global)
    coll_bytes: float          # collective wire bytes (per device)
    chips: int
    peak_flops: float
    hbm_bw: float
    ici_bw: float
    ici_links: int = 4          # v5e: 4 usable ICI links per chip (2D torus)

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * self.peak_flops)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * self.hbm_bw)

    @property
    def t_collective(self) -> float:
        # coll_bytes is per-device wire payload; each chip drives ici_links
        # links concurrently under ring/torus schedules.
        return self.coll_bytes / (self.ici_links * self.ici_bw)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time,
        }
