"""pjit train-step construction + sharding spec derivation (pod scale).

Shared by the dry-run (AOT lower/compile) and the real launcher: the same
``make_train_step`` output is either ``.lower().compile()``'d against
abstract inputs or executed on a live mesh.

Sample selection plugs in through the same ``SampleStrategy`` protocol the
host trainer uses: the launcher builds a strategy via
``repro.core.make_strategy``, each epoch's ``EpochPlan`` is sliced across
the data-parallel workers with ``plan_worker_indices`` (bit-identical to
the single-host index order), and ``plan_lr`` folds the plan's Eq. 8
factor into the step's learning rate.
"""
from __future__ import annotations

from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.strategy import EpochPlan
from repro.data.pipeline import worker_slice
from repro.dist.sharding import ParallelCtx
from repro.models.model import Model
from repro.optim.optimizers import Optimizer, make_optimizer

FSDP_THRESHOLD_BYTES = 1 << 30  # shard params over data axes above 1 GB/chip


def build_ctx(cfg: ArchConfig, mesh, *, fsdp: bool | None = None,
              seq_parallel_kv: bool = False, remat: bool = True,
              dp_only: bool = False, remat_policy: str = "nothing",
              moe_fsdp_mode: str = "gather") -> ParallelCtx:
    ctx = ParallelCtx(mesh=mesh, fsdp=False, seq_parallel_kv=seq_parallel_kv,
                      remat=remat, dp_only=dp_only, remat_policy=remat_policy,
                      moe_fsdp_mode=moe_fsdp_mode)
    if fsdp is None and mesh is not None:
        per_chip = cfg.param_count() * 2 / max(ctx.tp_size, 1)
        fsdp = per_chip > FSDP_THRESHOLD_BYTES or dp_only
    ctx.fsdp = bool(fsdp)
    return ctx


def make_train_step(model: Model, opt: Optimizer):
    def train_step(params, opt_state, batch, lr):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_and_metrics, has_aux=True)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        return params, opt_state, loss, metrics

    return train_step


# ---------------------------------------------------------------------------
# EpochPlan consumption (strategy protocol -> pod-scale step feeding)
# ---------------------------------------------------------------------------


def plan_worker_indices(plan: EpochPlan, world_size: int, rank: int,
                        batch_per_worker: int) -> np.ndarray:
    """One data-parallel worker's view of a plan's visible set.

    Every worker calls this on the *same* plan (strategies are seeded, so
    all hosts compute identical plans); the union of the per-rank slices,
    batch by batch, reproduces the single-host batch order exactly — the
    property elastic rescaling relies on (train/fault.py).
    """
    return worker_slice(plan.visible_indices, world_size, rank,
                        batch_per_worker)


def plan_lr(base_lr: float, plan: EpochPlan) -> float:
    """Fold the plan's Eq. 8 factor into the step LR."""
    return float(base_lr) * float(plan.lr_scale)


def plan_summary(plan: EpochPlan) -> dict:
    """One JSON-able record per epoch plan.

    What the launcher logs each epoch and benchmarks/selection_overhead.py
    aggregates: the plan's shape plus how many device->host syncs producing
    it cost (the device-resident plan step spends exactly one).
    """
    return {
        "epoch": int(plan.epoch),
        "visible": int(len(plan.visible_indices)),
        "hidden": int(len(plan.hidden_indices)),
        "moveback": int(len(plan.moveback_indices)),
        "max_fraction": float(plan.max_fraction),
        "hidden_fraction": float(plan.hidden_fraction),
        "lr_scale": float(plan.lr_scale),
        "needs_refresh": bool(plan.needs_refresh),
        "host_syncs": int(plan.host_syncs),
    }


def plan_global_batches(plan: EpochPlan, world_size: int,
                        batch_per_worker: int) -> Iterator[np.ndarray]:
    """Global-batch index arrays of shape (world_size * batch_per_worker,)
    in pjit layout: reshaping to (world_size, batch_per_worker) gives each
    rank's sub-batch, matching a batch array sharded over the data axes.

    By worker_slice's construction (trim, reshape (-1, W, B), take column
    r), global batch s is exactly the s-th consecutive W*B-chunk of the
    plan's visible set — so yield those chunks directly.
    """
    gb = world_size * batch_per_worker
    v = plan.visible_indices
    for start in range(0, (len(v) // gb) * gb, gb):
        yield v[start : start + gb]


def _pad_spec(spec: P, ndim: int) -> tuple:
    t = tuple(spec)
    return t + (None,) * (ndim - len(t))


def opt_state_specs(opt_name: str, param_specs: Any, params_abs: Any,
                    momentum: bool = True) -> Any:
    """PartitionSpec tree for the optimizer state (mirrors ZeRO sharding)."""
    if opt_name == "sgd":
        return param_specs if momentum else ()
    if opt_name == "adamw":
        return {"m": param_specs, "v": param_specs, "t": P()}
    if opt_name == "rmsprop":
        return {"v": param_specs, "m": param_specs}
    if opt_name == "adafactor":
        def one(spec, ab):
            s = _pad_spec(spec, ab.ndim)
            if ab.ndim >= 2:
                return {"r": P(*s[:-1]), "c": P(*(s[:-2] + (s[-1],)))}
            return {"v": P(*s)}
        return {
            "s": jax.tree.map(one, param_specs, params_abs,
                              is_leaf=lambda x: isinstance(x, P)),
            "t": P(),
        }
    raise ValueError(opt_name)


def shardings_for(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def abstract_train_state(model: Model, opt: Optimizer, dtype=jnp.bfloat16):
    """(params_abs, opt_abs, param_specs, opt_specs) — all abstract."""
    params_abs = model.abstract_params(dtype)
    param_specs = model.param_specs(dtype)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    momentum = bool(jax.tree.leaves(opt_abs)) if opt.name == "sgd" else True
    opt_specs = opt_state_specs(opt.name, param_specs, params_abs, momentum)
    return params_abs, opt_abs, param_specs, opt_specs


def optimizer_for(cfg: ArchConfig) -> Optimizer:
    if cfg.optimizer == "adafactor":
        return make_optimizer("adafactor")
    if cfg.optimizer == "adamw":
        # f32 moments (standard); ZeRO-sharded with the params
        return make_optimizer("adamw", state_dtype=jnp.float32)
    return make_optimizer(cfg.optimizer)
