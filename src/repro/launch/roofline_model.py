"""Analytic HBM-traffic model for the roofline memory term.

XLA-CPU's ``cost_analysis()['bytes accessed']`` counts every HLO op's
operands pre-fusion, over-counting TPU HBM traffic by >10x and non-linearly
in depth (measured; EXPERIMENTS.md §Dry-run).  The memory term therefore
comes from this explicit model of per-step HBM bytes; the XLA number is kept
in the cell JSON as ``hlo_bytes_xla`` for reference.

Assumptions (stated once, used everywhere):
  * weights bf16 (2 B); optimizer moments f32 (AdamW) / factored (Adafactor);
  * scan-over-layers remat (nothing_saveable): weights read 3x in training
    (fwd, recompute, bwd), one (B,S,d) carry saved+reloaded per layer;
  * attention runs as a fused flash kernel (scores never touch HBM) —
    that is the TPU-target configuration shipped in kernels/;
  * MoE: all resident expert weights stream from HBM each step (dispatch
    touches every local expert); capacity buffers stay on-chip;
  * decode reads the whole KV cache once per step, writes one position.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeSpec

BF16 = 2
F32 = 4
I32 = 4
U32 = 4


def _opt_bytes_per_param(optimizer: str) -> float:
    """HBM bytes/param for grads + optimizer state r/w + param write."""
    grad = 2 * BF16          # grad write (bwd) + read (opt)
    pwrite = BF16
    if optimizer == "adamw":
        return grad + pwrite + 4 * F32          # m r/w + v r/w in f32
    if optimizer == "adafactor":
        return grad + pwrite + 1                # factored state ~ negligible
    # sgd-momentum / rmsprop: state in param dtype
    return grad + pwrite + 2 * BF16


def analytic_hbm_bytes(cfg: ArchConfig, shape: ShapeSpec,
                       optimizer: str = "adamw",
                       weight_bytes: int = BF16) -> dict[str, float]:
    """Global HBM bytes per step, broken into terms.

    ``weight_bytes``: serving-weight precision (2 = bf16, 1 = fp8-e4m3 —
    the quantized-serving §Perf variant).
    """
    P = cfg.param_count()
    b, s = shape.global_batch, shape.seq_len
    d, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    L_total = L + cfg.num_encoder_layers
    terms: dict[str, float] = {}
    if shape.kind == "train":
        tokens = b * s
        terms["weights"] = 3 * BF16 * P          # fwd + remat + bwd
        terms["optimizer"] = _opt_bytes_per_param(optimizer) * P
        # one saved residual carry per layer: write fwd, read bwd
        terms["activations"] = 2 * BF16 * L_total * tokens * d
        # logits: fwd write + bwd read + grad write (big-vocab dominant)
        terms["logits"] = 3 * BF16 * tokens * V
        terms["embeds"] = 2 * BF16 * tokens * d
    elif shape.kind == "prefill":
        tokens = b * s
        terms["weights"] = weight_bytes * P
        terms["activations"] = BF16 * L_total * tokens * d
        if cfg.num_heads:
            kv = 2 * L * tokens * cfg.num_kv_heads * cfg.resolved_head_dim
            terms["kv_cache_write"] = weight_bytes * kv
        terms["logits"] = BF16 * b * V
    else:  # decode: one token, cache length s
        terms["weights"] = weight_bytes * P
        if cfg.num_heads:
            s_cache = s
            if cfg.attn_window is not None and cfg.sub_quadratic:
                s_cache = min(s, cfg.attn_window)
            kv = 2 * L * b * s_cache * cfg.num_kv_heads * cfg.resolved_head_dim
            terms["kv_cache_read"] = weight_bytes * kv
        if cfg.ssm is not None:
            di = cfg.ssm.d_inner or cfg.ssm.expand * d
            nh = di // cfg.ssm.head_dim
            state = L * b * nh * cfg.ssm.state_dim * cfg.ssm.head_dim
            terms["ssm_state"] = 2 * F32 * state     # read + write
        terms["logits"] = BF16 * b * V
    terms["total"] = sum(terms.values())
    return terms


def kernel_hbm_bytes(kernel: str, **shape) -> int:
    """Minimal HBM traffic of one Pallas kernel call, in bytes.

    The per-kernel analogue of ``analytic_hbm_bytes``: every operand read
    once + every output written once (the streaming kernels in ``kernels/``
    are single-pass by construction, so this floor is what they should
    actually move).  ``benchmarks/kernel_micro.py`` divides measured time by
    these bytes for the ``gbps_kernel`` column and the roofline fraction
    against the machine's measured stream bandwidth — the schema recorded in
    ``results/BENCH_kernels.json``.

    Shapes (keyword-only, mirroring each kernel's bench record):
      flash_attention: b, s, hq, hkv, d     (q + k + v read, o written; f32)
      ssd_scan:        b, s, nh, p, n       (x/dt/b/c read, y + state written)
      loss_confidence: t, v                 (logits + labels read; 3 outs)
      fused_scoring:   t, v                 (same traffic as loss_confidence)
      loss_histogram:  n [, bins]           (loss + valid read, hist written)
      loss_minmax:     n                    (loss + valid read, 2 scalars)
      rank_select:     n                    (5 streaming passes: 4 radix
                                             histograms + the select pass
                                             over the uint32 keys + mask out)
    """
    if kernel == "flash_attention":
        b, s, hq, hkv, d = (shape[k] for k in ("b", "s", "hq", "hkv", "d"))
        return F32 * (b * s * hq * d * 2 + b * s * hkv * d * 2)
    if kernel == "ssd_scan":
        b, s, nh, p, n = (shape[k] for k in ("b", "s", "nh", "p", "n"))
        return F32 * (b * s * nh * p * 2      # x read + y written
                      + b * s * nh            # dt
                      + b * s * n * 2         # b + c
                      + b * nh * n * p)       # final state written
    if kernel in ("loss_confidence", "fused_scoring"):
        t, v = shape["t"], shape["v"]
        return F32 * t * v + I32 * t + 3 * F32 * t
    if kernel == "loss_histogram":
        n = shape["n"]
        return F32 * n + n + I32 * shape.get("bins", 512)
    if kernel == "loss_minmax":
        n = shape["n"]
        return F32 * n + n + 2 * F32
    if kernel == "rank_select":
        n = shape["n"]
        return 5 * U32 * n + n
    raise ValueError(f"no HBM byte model for kernel {kernel!r}")
