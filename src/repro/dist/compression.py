"""Gradient compression with error feedback (EF-SGD style).

Per-leaf uniform 8-bit quantization: each step quantizes ``g + e`` (gradient
plus the carried error) to 255 levels of its own max-abs scale and carries
the quantization residual into the next step.  Error feedback makes the
*accumulated* compressed gradients track the true gradient sum to within one
step's quantization error, so convergence is unaffected while the wire
format shrinks 4x (the collective would ship int8 + one f32 scale per leaf).

Pure jnp, shape-preserving, jit/pjit-safe.  Wired in behind
``TrainConfig.grad_compression``: the single-device trainer folds it into
its jitted train step, and the mesh-sharded step applies it to the folded
(replicated) gradients before the optimizer update — deterministic and
mesh-size-invariant, so the ``(1,)`` vs ``(8,)`` bit-identity bar holds
with compression on (``tests/test_mesh_trainer.py``).  The pjit pod path
can likewise apply it before the grad psum.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

_LEVELS = 127.0  # symmetric int8


def init_error_feedback(params: Any) -> Any:
    """Zero residual tree matching ``params`` (call once at startup)."""
    return jax.tree.map(lambda p: jnp.zeros_like(p), params)


def compress_grads(grads: Any, ef: Any) -> tuple[Any, Any]:
    """Quantize ``grads + ef``; return (compressed grads, new residuals)."""

    def one(g, e):
        v = g + e
        scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-12) / _LEVELS
        q = jnp.round(v / scale) * scale
        q = q.astype(g.dtype)
        return q, (v - q).astype(g.dtype)

    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_e = jax.tree.leaves(ef)
    out = [one(g, e) for g, e in zip(leaves_g, leaves_e)]
    cg = treedef.unflatten([q for q, _ in out])
    new_ef = treedef.unflatten([r for _, r in out])
    return cg, new_ef
