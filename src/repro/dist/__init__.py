"""Distributed substrate: mesh-aware sharding specs, shard_map compat and
gradient compression (error-feedback quantization)."""
from repro.dist.sharding import (  # noqa: F401
    ParallelCtx, shard_map_compat, spec_tree_for,
)
from repro.dist.compression import (  # noqa: F401
    compress_grads, init_error_feedback,
)
