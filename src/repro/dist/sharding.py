"""Logical-axis sharding: ``ParallelCtx`` maps model-code logical names to
mesh axes.

Model code annotates params and activations with *logical* axis names
(``"batch"``, ``"fsdp"``, ``"tp"``, ``"exp"``, ``"seq_tp"``); the context
resolves them against whatever mesh the launcher built:

  - ``"batch"``   -> the data axes (``("data",)`` or ``("pod", "data")``)
  - ``"fsdp"``    -> the data axes, but only when ``ctx.fsdp`` (ZeRO-style
                     param sharding above the size threshold)
  - ``"tp"``      -> the ``"model"`` axis (tensor parallelism)
  - ``"exp"``     -> the ``"model"`` axis (expert parallelism; same axis,
                     different collective pattern — see models/moe.py)
  - ``"seq_tp"``  -> the ``"model"`` axis, only under sequence-parallel KV
  - ``None``      -> replicated

A dim is only sharded when its size divides evenly over the mapped mesh
axes — e.g. GQA KV heads that don't divide the tp degree stay replicated
(models/attention.py relies on this).  With ``mesh=None`` every spec is
fully replicated and ``cs`` is the identity, so the same model code runs
single-device (tests) and on the pod mesh unchanged.

Beyond logical specs, ``ParallelCtx`` carries the row-sharding helpers the
mesh-sharded trainer and the selection engine build on
(``rows_spec`` / ``shard_rows`` / ``constrain_rows`` / ``replicate``):
per-sample ``(N, ...)`` state lives split over the data axes, train state
replicated — all identity off-mesh, so every call site is mesh-agnostic.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # newer jax: top-level export
    from jax import shard_map as _sm_mod
    _shard_map = getattr(_sm_mod, "shard_map", _sm_mod)
except ImportError:  # pragma: no cover - jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_MODEL_AXIS = "model"
_DATA_AXES = ("pod", "data")


def shard_map_compat(f, mesh, in_specs, out_specs, check_vma: bool = False):
    """shard_map across jax versions (``check_vma`` vs older ``check_rep``)."""
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma)
    except TypeError:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


@dataclasses.dataclass
class ParallelCtx:
    """Resolves logical axis names against a concrete mesh (or none)."""

    mesh: Mesh | None = None
    fsdp: bool = False
    seq_parallel_kv: bool = False
    remat: bool = False
    dp_only: bool = False              # fold "model" into the data axes
    remat_policy: str = "nothing"      # "nothing" | "dots"
    moe_fsdp_mode: str = "gather"      # "gather" (ZeRO-3) | "partial"

    # -- mesh-derived views --------------------------------------------------

    @property
    def axis_names(self) -> tuple[str, ...]:
        return tuple(self.mesh.axis_names) if self.mesh is not None else ()

    @property
    def dp_axes(self) -> tuple[str, ...]:
        """Data-parallel axes in mesh order (pod-major)."""
        names = self.axis_names
        dp = tuple(a for a in names if a in _DATA_AXES)
        if self.dp_only and _MODEL_AXIS in names:
            dp = dp + (_MODEL_AXIS,)
        return dp

    @property
    def tp_axis(self) -> str | None:
        if self.dp_only or self.mesh is None:
            return None
        return _MODEL_AXIS if _MODEL_AXIS in self.axis_names else None

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis] if self.tp_axis else 1

    @property
    def dp_size(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.dp_axes) if self.mesh else 1

    # -- logical resolution --------------------------------------------------

    def _axes_for(self, name: str | None) -> tuple[str, ...]:
        if name is None:
            return ()
        if name == "batch":
            return self.dp_axes
        if name == "fsdp":
            return self.dp_axes if self.fsdp else ()
        if name in ("tp", "exp"):
            return (self.tp_axis,) if self.tp_axis else ()
        if name == "seq_tp":
            return ((self.tp_axis,) if self.seq_parallel_kv and self.tp_axis
                    else ())
        raise ValueError(f"unknown logical axis {name!r}")

    def spec(self, *logical: str | None, dims: tuple[int, ...] | None = None) -> P:
        """PartitionSpec for one array given per-dim logical names.

        ``dims`` (the array shape) enables the divisibility guard: a dim
        whose size doesn't divide over the mapped mesh axes is replicated.
        """
        if self.mesh is None:
            return P()
        entries: list[Any] = []
        used: set[str] = set()
        for i, name in enumerate(logical):
            axes = tuple(a for a in self._axes_for(name) if a not in used)
            if axes and dims is not None:
                span = math.prod(self.mesh.shape[a] for a in axes)
                if dims[i] % span != 0:
                    axes = ()
            used.update(axes)
            if not axes:
                entries.append(None)
            elif len(axes) == 1:
                entries.append(axes[0])
            else:
                entries.append(axes)
        return P(*entries)

    def cs(self, x: jax.Array, *logical: str | None) -> jax.Array:
        """with_sharding_constraint under the logical mapping (no-op off-mesh)."""
        if self.mesh is None:
            return x
        spec = self.spec(*logical, dims=tuple(x.shape))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    # -- row sharding helpers (SampleState / per-sample arrays) --------------

    @property
    def rows_spec(self) -> P:
        """PartitionSpec sharding dim 0 over the data axes (``P("data")`` on a
        pure data mesh; ``P(("pod", "data"))`` on the pod mesh; ``P()`` with
        no mesh)."""
        dp = self.dp_axes
        if not dp:
            return P()
        return P(dp[0] if len(dp) == 1 else dp)

    def check_rows(self, num_samples: int) -> None:
        """Fail fast when ``(N, ...)`` per-sample state cannot row-shard.

        Called by every sampler that keeps row-sharded state; off-mesh (or
        when N divides the data-parallel degree) it is a no-op.
        """
        if self.mesh is not None and num_samples % self.dp_size:
            raise ValueError(
                f"num_samples={num_samples} must be a multiple of the "
                f"data-parallel degree {self.dp_size} to row-shard "
                "SampleState")

    def shard_rows(self, tree: Any) -> Any:
        """device_put a pytree of ``(N, ...)`` arrays row-sharded over the
        data axes (e.g. ``SampleState``).  N must be a multiple of
        ``dp_size`` (``check_rows``).  Identity with no mesh."""
        if self.mesh is None:
            return tree
        return jax.device_put(tree, NamedSharding(self.mesh, self.rows_spec))

    def replicate(self, tree: Any) -> Any:
        """device_put a pytree fully replicated over the mesh (params,
        optimizer state, RNG keys).  Identity with no mesh."""
        if self.mesh is None:
            return tree
        return jax.device_put(tree, NamedSharding(self.mesh, P()))

    def constrain_rows(self, tree: Any) -> Any:
        """In-jit ``with_sharding_constraint`` pinning dim 0 of every leaf to
        the data axes — used to keep ``SampleState`` sharded across the fused
        observe scatter.  Identity with no mesh."""
        if self.mesh is None:
            return tree
        s = NamedSharding(self.mesh, self.rows_spec)
        return jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(x, s), tree)


def _is_logical(x: Any) -> bool:
    return isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)


def spec_tree_for(logical: Any, ctx: ParallelCtx, abstract: Any = None) -> Any:
    """Map a tree of logical-axis tuples to a tree of PartitionSpecs.

    ``abstract`` (matching tree of ShapeDtypeStructs) supplies the shapes
    for the divisibility guard; without it, specs are taken at face value.
    """
    if abstract is None:
        return jax.tree.map(lambda lg: ctx.spec(*lg), logical,
                            is_leaf=_is_logical)
    return jax.tree.map(
        lambda lg, ab: ctx.spec(*lg, dims=tuple(ab.shape)),
        logical, abstract, is_leaf=_is_logical)
