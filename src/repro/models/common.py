"""Shared model building blocks: param definitions, norms, rotary, inits.

Parameters are described declaratively by ``ParamDef`` pytrees so that the
same structure yields (a) ``jax.eval_shape``-compatible abstract params for
the dry-run, (b) initialized values, and (c) logical-axis PartitionSpecs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Logical = tuple  # tuple of logical axis names / None, one per dim

# ---------------------------------------------------------------------------
# Layer-loop scan with a controllable unroll factor.
#
# XLA's cost analysis counts a while-loop body ONCE regardless of trip count,
# so the dry-run fully unrolls the layer loop (``with scan_unroll(L):``) to
# obtain true FLOP / byte / collective totals for the roofline; training and
# serving keep the rolled loop (fast compiles, small HLO).
# ---------------------------------------------------------------------------
import contextlib
import contextvars

_SCAN_UNROLL: contextvars.ContextVar[int] = contextvars.ContextVar(
    "scan_unroll", default=1)


@contextlib.contextmanager
def scan_unroll(n: int):
    tok = _SCAN_UNROLL.set(max(int(n), 1))
    try:
        yield
    finally:
        _SCAN_UNROLL.reset(tok)


def layer_scan(f, init, xs):
    return jax.lax.scan(f, init, xs, unroll=_SCAN_UNROLL.get())


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical: Logical            # len == len(shape)
    init: str = "normal"        # normal | zeros | ones | embed | conv
    scale: float | None = None  # override init scale
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def stack_defs(defs: Any, num_layers: int) -> Any:
    """Prepend a layer dim to every ParamDef (for scan-over-layers)."""
    return jax.tree.map(
        lambda d: ParamDef((num_layers, *d.shape), (None, *d.logical),
                           d.init, d.scale, d.dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def abstract_params(defs: Any, dtype=None) -> Any:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype or d.dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def logical_tree(defs: Any) -> Any:
    return jax.tree.map(lambda d: d.logical, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def init_params(rng: jax.Array, defs: Any, dtype=None) -> Any:
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(rng, len(leaves))

    def _one(key, d: ParamDef):
        dt = dtype or d.dtype
        if d.init == "zeros":
            return jnp.zeros(d.shape, dt)
        if d.init == "ones":
            return jnp.ones(d.shape, dt)
        if d.init == "a_log":  # mamba A_log init: log(uniform[1,16])
            u = jax.random.uniform(key, d.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(dt)
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        if d.init == "embed":
            scale = d.scale or 1.0
        else:
            scale = d.scale or (1.0 / max(fan_in, 1)) ** 0.5
        return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(dt)

    return jax.tree.unflatten(treedef, [_one(k, d) for k, d in zip(keys, leaves)])


# ---------------------------------------------------------------------------
# Numeric building blocks
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * w.astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def gated_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
              w_down: jax.Array) -> jax.Array:
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, w_gate.astype(x.dtype)))
    u = jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", g * u, w_down.astype(x.dtype))
