"""GQA attention: training/prefill (chunked-q flash-style) and decode paths.

Layouts:
  x       (B, S, d_model)
  q       (B, S, Hq, Dh)   — Hq sharded over "tp" when divisible
  k, v    (B, S, Hkv, Dh)  — replicated over "tp" when Hkv %% tp != 0 (GQA)
  cache   (B, S_max, Hkv, Dh) — batch-sharded; optionally seq-sharded over
          "model" (sequence-parallel flash-decode, see ``decode_attend_sp``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, rms_norm, rope

NEG_INF = -1e30


def attn_param_defs(d_model: int, n_q: int, n_kv: int, dh: int,
                    qk_norm: bool) -> dict:
    defs = {
        "wq": ParamDef((d_model, n_q, dh), (("fsdp", "tp", None))),
        "wk": ParamDef((d_model, n_kv, dh), (("fsdp", "tp", None))),
        "wv": ParamDef((d_model, n_kv, dh), (("fsdp", "tp", None))),
        "wo": ParamDef((n_q, dh, d_model), (("tp", None, "fsdp"))),
    }
    if qk_norm:
        defs["q_norm"] = ParamDef((dh,), ((None,)), init="ones")
        defs["k_norm"] = ParamDef((dh,), ((None,)), init="ones")
    return defs


def project_qkv(p: dict, x: jax.Array, positions: jax.Array,
                theta: float, qk_norm: bool, norm_eps: float):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if qk_norm:
        q = rms_norm(q, p["q_norm"], norm_eps)
        k = rms_norm(k, p["k_norm"], norm_eps)
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    return q, k, v


def _grouped_scores(q5, k, scale):
    # q5: (B, Q, Hkv, G, Dh), k: (B, K, Hkv, Dh) -> (B, Hkv, G, Q, K) f32
    return jnp.einsum("bqhgd,bkhd->bhgqk", q5, k,
                      preferred_element_type=jnp.float32) * scale


def attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
           causal: bool = True, window: int | None = None,
           is_global: jax.Array | bool = True,
           q_chunk: int = 512) -> jax.Array:
    """Full-sequence attention with chunked-q online evaluation.

    ``window``: sliding-window size; applied unless ``is_global`` (a traced
    bool works — hybrid archs mix global and SWA layers inside one scan).
    """
    b, s, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = dh ** -0.5
    q5 = q.reshape(b, s, hkv, g, dh)
    kpos = jnp.arange(s)

    use_window = window is not None
    win = window if use_window else s

    def _block(qc, q0):
        # qc: (B, Cq, Hkv, G, Dh); q0: first global q position of the chunk.
        cq = qc.shape[1]
        scores = _grouped_scores(qc, k, scale)  # (B,Hkv,G,Cq,S) f32
        qpos = q0 + jnp.arange(cq)
        mask = jnp.ones((cq, s), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if use_window:
            wmask = mask & (qpos[:, None] - kpos[None, :] < win)
            mask = jnp.where(jnp.asarray(is_global), mask, wmask)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)

    if s > q_chunk and s % q_chunk == 0:
        nc = s // q_chunk
        qs = q5.reshape(b, nc, q_chunk, hkv, g, dh).transpose(1, 0, 2, 3, 4, 5)

        def body(_, xs):
            qc, idx = xs
            return None, _block(qc, idx * q_chunk)

        _, out = jax.lax.scan(body, None, (qs, jnp.arange(nc)))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, hq, dh)
    else:
        out = _block(q5, 0).reshape(b, s, hq, dh)
    return out


def cross_attend(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Encoder-decoder cross attention (no mask, no rope on this path)."""
    b, s, hq, dh = q.shape
    hkv = k.shape[2]
    q5 = q.reshape(b, s, hkv, hq // hkv, dh)
    scores = _grouped_scores(q5, k, dh ** -0.5)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v).reshape(b, s, hq, dh)


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------


def decode_attend(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                  cache_len: jax.Array, *, window: int | None = None,
                  is_global: jax.Array | bool = True) -> jax.Array:
    """One-token attention against a (B, S_max, Hkv, Dh) cache."""
    # fp8 caches are a storage format; compute in the query dtype.
    k_cache = k_cache.astype(q.dtype)
    v_cache = v_cache.astype(q.dtype)
    b, one, hq, dh = q.shape
    hkv = k_cache.shape[2]
    s = k_cache.shape[1]
    q5 = q.reshape(b, 1, hkv, hq // hkv, dh)
    scores = _grouped_scores(q5, k_cache, dh ** -0.5)  # (B,Hkv,G,1,S)
    kpos = jnp.arange(s)
    mask = kpos < cache_len
    if window is not None:
        wmask = mask & (cache_len - 1 - kpos < window)
        mask = jnp.where(jnp.asarray(is_global), mask, wmask)
    scores = jnp.where(mask[None, None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v_cache).reshape(b, 1, hq, dh)


def decode_attend_sp(q: jax.Array, k_loc: jax.Array, v_loc: jax.Array,
                     cache_len: jax.Array, axis: str = "model") -> jax.Array:
    """Sequence-parallel flash-decode (runs under shard_map over ``axis``).

    The KV cache's sequence dim is sharded over the model axis; each shard
    computes local (max, exp-sum, weighted-V) and combines with one pmax +
    two psums of O(B*Hq*Dh) — instead of replicating an O(S) cache 16x.
    """
    k_loc = k_loc.astype(q.dtype)
    v_loc = v_loc.astype(q.dtype)
    b, one, hq, dh = q.shape
    hkv = k_loc.shape[2]
    s_loc = k_loc.shape[1]
    shard = jax.lax.axis_index(axis)
    kpos = shard * s_loc + jnp.arange(s_loc)
    q5 = q.reshape(b, 1, hkv, hq // hkv, dh)
    scores = _grouped_scores(q5, k_loc, dh ** -0.5)  # (B,Hkv,G,1,S_loc) f32
    scores = jnp.where((kpos < cache_len)[None, None, None, None], scores, NEG_INF)
    m_loc = jnp.max(scores, axis=-1, keepdims=True)
    m = jax.lax.pmax(m_loc, axis)
    p = jnp.exp(scores - m)
    den = jax.lax.psum(jnp.sum(p, axis=-1), axis)          # (B,Hkv,G,1)
    num = jax.lax.psum(
        jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(q.dtype), v_loc), axis)
    out = num / den[..., None].astype(q.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, 1, hq, dh)


def update_cache(k_cache, v_cache, k_new, v_new, idx):
    """Write one token at position ``idx`` (ring-buffer for SWA handled by
    caller passing idx %% window)."""
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, idx, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, idx, axis=1)
    return k_cache, v_cache
