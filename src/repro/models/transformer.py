"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

One scan-over-layers implementation; the per-layer block is selected by
``cfg.family``.  All heavy activations carry logical sharding constraints via
the ``ParallelCtx`` so the same code runs on 1 CPU device and on the
(pod, data, model) production mesh.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import ParallelCtx
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import layer_scan as _scan
from repro.models.common import (
    ParamDef, gated_mlp, rms_norm, stack_defs,
)

def _remat_policy(ctx):
    if getattr(ctx, "remat_policy", "nothing") == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    return jax.checkpoint_policies.nothing_saveable


VLM_PATCH_DIM = 1024  # CLIP-style frontend stub output dim (llava projector in)


# ---------------------------------------------------------------------------
# Parameter structure
# ---------------------------------------------------------------------------


def _mlp_defs(d: int, ff: int) -> dict:
    return {
        "w_gate": ParamDef((d, ff), ("fsdp", "tp")),
        "w_up": ParamDef((d, ff), ("fsdp", "tp")),
        "w_down": ParamDef((ff, d), ("tp", "fsdp")),
    }


def _block_defs(cfg: ArchConfig, moe_mode: str = "gather") -> dict:
    d = cfg.d_model
    defs: dict[str, Any] = {"ln1": ParamDef((d,), (None,), init="ones")}
    if cfg.family in ("dense", "moe", "hybrid", "vlm"):
        defs["attn"] = attn.attn_param_defs(
            d, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim,
            cfg.qk_norm)
        defs["ln2"] = ParamDef((d,), (None,), init="ones")
    if cfg.family == "moe":
        defs["moe"] = moe_mod.moe_param_defs(d, cfg.moe, moe_mode)
    elif cfg.family in ("dense", "vlm", "hybrid"):
        defs["mlp"] = _mlp_defs(d, cfg.d_ff)
    if cfg.family in ("ssm", "hybrid"):
        di = cfg.ssm.d_inner or cfg.ssm.expand * d
        defs["ssm"] = ssm_mod.ssm_param_defs(d, cfg.ssm, di)
    return defs


def param_defs(cfg: ArchConfig, moe_mode: str = "gather") -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    defs: dict[str, Any] = {
        "embed": ParamDef((v, d), ("tp", "fsdp"), init="embed", scale=0.02),
        "out_norm": ParamDef((d,), (None,), init="ones"),
        "layers": stack_defs(_block_defs(cfg, moe_mode), cfg.num_layers),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, v), ("fsdp", "tp"))
    if cfg.family == "vlm":
        defs["mm_proj"] = ParamDef((VLM_PATCH_DIM, d), (None, "fsdp"))
    return defs


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _d_inner(cfg: ArchConfig) -> int:
    return cfg.ssm.d_inner or cfg.ssm.expand * cfg.d_model


def _block(cfg: ArchConfig, ctx: ParallelCtx, p: dict, x: jax.Array,
           positions: jax.Array, is_global: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One layer. Returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.family == "ssm":
        x = x + ssm_mod.ssm_forward(p["ssm"], h, cfg.ssm, _d_inner(cfg),
                                    cfg.norm_eps)
        return x, aux
    q, k, v = attn.project_qkv(p["attn"], h, positions, cfg.rope_theta,
                               cfg.qk_norm, cfg.norm_eps)
    q = ctx.cs(q, "batch", None, "tp", None)
    k = ctx.cs(k, "batch", None, "tp", None)
    a = attn.attend(q, k, v, causal=True, window=cfg.attn_window,
                    is_global=is_global)
    a = jnp.einsum("bshk,hkd->bsd", a, p["attn"]["wo"].astype(x.dtype))
    if cfg.family == "hybrid":
        s = ssm_mod.ssm_forward(p["ssm"], h, cfg.ssm, _d_inner(cfg),
                                cfg.norm_eps)
        x = x + 0.5 * (a + s)  # hymba: mean-fused parallel heads
    else:
        x = x + a
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = moe_mod.moe_ffn(p["moe"], h2, cfg.moe, ctx)
        x = x + y
    else:
        x = x + gated_mlp(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                          p["mlp"]["w_down"])
    x = ctx.cs(x, "batch", None, None)
    return x, aux


def global_layer_flags(cfg: ArchConfig) -> jax.Array:
    """Per-layer bool: True = full/global attention, False = sliding window.

    Dense archs: all True. Hymba: 3 global layers (first/middle/last) unless
    running the long-context serve config where all layers are SWA (the
    config sets attn_window and we mark globals only when window is set).
    """
    L = cfg.num_layers
    if cfg.attn_window is None:
        return jnp.ones((L,), bool)
    flags = [i in (0, L // 2, L - 1) for i in range(L)]
    return jnp.asarray(flags)


def _scan_layers(cfg: ArchConfig, ctx: ParallelCtx, params: dict,
                 x: jax.Array, positions: jax.Array,
                 flags: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    if flags is None:
        flags = global_layer_flags(cfg)

    def body(carry, xs):
        x, aux = carry
        layer_p, flag = xs
        x, a = _block(cfg, ctx, layer_p, x, positions, flag)
        return (x, aux + a), None

    fn = body
    if ctx.remat:
        fn = jax.checkpoint(body, policy=_remat_policy(ctx))
    (x, aux), _ = _scan(fn, (x, jnp.float32(0.0)),
                               (params["layers"], flags))
    return x, aux


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def embed_inputs(cfg: ArchConfig, ctx: ParallelCtx, params: dict,
                 batch: dict) -> tuple[jax.Array, jax.Array]:
    """Returns (x (B,S,d), loss_mask (B,S))."""
    tokens = batch["tokens"]
    x = params["embed"].astype(_cdtype(params))[tokens]
    mask = batch.get("mask", jnp.ones(tokens.shape, bool))
    if cfg.family == "vlm" and "patch_embeds" in batch:
        pe = jnp.einsum("bpd,de->bpe", batch["patch_embeds"].astype(x.dtype),
                        params["mm_proj"].astype(x.dtype))
        x = jnp.concatenate([pe, x], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros(pe.shape[:2], bool), mask], axis=1)
    return ctx.cs(x, "batch", None, None), mask


def _cdtype(params) -> jnp.dtype:
    dt = params["embed"].dtype
    # fp8 is a STORAGE dtype (quantized serving); compute stays bf16.
    if dt.itemsize == 1:
        return jnp.bfloat16
    return dt


def logits_fn(cfg: ArchConfig, params: dict, x: jax.Array) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))


def forward(cfg: ArchConfig, ctx: ParallelCtx, params: dict,
            batch: dict) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full training forward. Returns (logits, loss_mask, moe_aux)."""
    x, mask = embed_inputs(cfg, ctx, params, batch)
    positions = jnp.arange(x.shape[1])[None, :]
    x, aux = _scan_layers(cfg, ctx, params, x, positions)
    x = rms_norm(x, params["out_norm"], cfg.norm_eps)
    logits = logits_fn(cfg, params, x)
    return ctx.cs(logits, "batch", None, "tp"), mask, aux


def token_metrics(logits: jax.Array, labels: jax.Array):
    """Per-token (ce, correct, pmax) — the pure-jnp oracle the
    ``loss_confidence`` Pallas kernel reproduces."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    mx = jnp.max(lf, axis=-1)
    am = jnp.argmax(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    ce = lse - gold
    pmax = jnp.exp(mx - lse)
    return ce, am == labels, pmax


def per_sample_metrics(cfg: ArchConfig, logits: jax.Array, labels: jax.Array,
                       mask: jax.Array, pa_threshold: float = 0.5):
    """Sequence-level (loss, PA, PC) — KAKURENBO's importance signals.

    For LMs a "sample" is a sequence: loss = mean token CE, PC = mean max
    softmax prob, PA = token accuracy >= pa_threshold (DESIGN.md Sec. 3).
    ``labels``/``mask`` cover only the text positions (VLM prefixes masked).
    """
    ce, correct, pmax = token_metrics(logits, labels)
    m = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(m, axis=-1), 1.0)
    loss = jnp.sum(ce * m, axis=-1) / denom
    acc = jnp.sum(correct.astype(jnp.float32) * m, axis=-1) / denom
    pc = jnp.sum(pmax * m, axis=-1) / denom
    return loss, acc >= pa_threshold, pc


# ---------------------------------------------------------------------------
# Serving: prefill + decode with stacked per-layer caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, ring: bool = False) -> dict:
    """Stacked (L, ...) caches.

    ``ring=True`` (long-context serve for SWA archs): the attention cache is a
    ring buffer of size ``attn_window`` and every layer attends SWA — the
    sub-quadratic mode that makes the 512K-ctx cells feasible.
    """
    L = cfg.num_layers
    cache: dict[str, Any] = {"len": jnp.zeros((), jnp.int32)}
    if cfg.family != "ssm" and cfg.num_heads:
        s_cache = max_len
        if ring:
            assert cfg.attn_window is not None, "ring cache needs a window"
            s_cache = min(max_len, cfg.attn_window)
        hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        cache["k"] = jnp.zeros((L, batch, s_cache, hkv, dh), dtype)
        cache["v"] = jnp.zeros((L, batch, s_cache, hkv, dh), dtype)
    if cfg.family in ("ssm", "hybrid"):
        di = _d_inner(cfg)
        n, nh, hd = cfg.ssm.state_dim, di // cfg.ssm.head_dim, cfg.ssm.head_dim
        conv_dim = di + 2 * n
        cache["ssm_state"] = jnp.zeros((L, batch, nh, n, hd), jnp.float32)
        cache["conv_buf"] = jnp.zeros(
            (L, batch, cfg.ssm.conv_width - 1, conv_dim), dtype)
    return cache


def _decode_block(cfg: ArchConfig, ctx: ParallelCtx, p: dict, x: jax.Array,
                  layer_cache: dict, cache_len: jax.Array,
                  is_global: jax.Array) -> tuple[jax.Array, dict]:
    new_cache = dict(layer_cache)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    positions = cache_len[None, None] + jnp.zeros((x.shape[0], 1), jnp.int32)
    if cfg.family == "ssm":
        y, sc = ssm_mod.ssm_decode_step(
            p["ssm"], h, {"state": layer_cache["ssm_state"],
                          "conv_buf": layer_cache["conv_buf"]},
            cfg.ssm, _d_inner(cfg), cfg.norm_eps)
        new_cache["ssm_state"], new_cache["conv_buf"] = sc["state"], sc["conv_buf"]
        return x + y, new_cache
    q, k, v = attn.project_qkv(p["attn"], h, positions, cfg.rope_theta,
                               cfg.qk_norm, cfg.norm_eps)
    s_cache = layer_cache["k"].shape[1]
    ring = cfg.attn_window is not None and s_cache <= cfg.attn_window
    write_idx = cache_len % s_cache if ring else cache_len
    kc, vc = attn.update_cache(layer_cache["k"], layer_cache["v"],
                               k.astype(layer_cache["k"].dtype),
                               v.astype(layer_cache["v"].dtype), write_idx)
    new_cache["k"], new_cache["v"] = kc, vc
    if ctx.seq_parallel_kv and ctx.mesh is not None:
        a = _sp_decode_attend(ctx, q, kc, vc, cache_len + 1)
    elif ring:
        # Ring buffer: every resident slot is inside the window by
        # construction; only mask the not-yet-written slots.
        a = attn.decode_attend(q, kc, vc, jnp.minimum(cache_len + 1, s_cache))
    else:
        a = attn.decode_attend(q, kc, vc, cache_len + 1,
                               window=cfg.attn_window, is_global=is_global)
    a = jnp.einsum("bshk,hkd->bsd", a, p["attn"]["wo"].astype(x.dtype))
    if cfg.family == "hybrid":
        y, sc = ssm_mod.ssm_decode_step(
            p["ssm"], h, {"state": layer_cache["ssm_state"],
                          "conv_buf": layer_cache["conv_buf"]},
            cfg.ssm, _d_inner(cfg), cfg.norm_eps)
        new_cache["ssm_state"], new_cache["conv_buf"] = sc["state"], sc["conv_buf"]
        x = x + 0.5 * (a + y)
    else:
        x = x + a
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = moe_mod.moe_ffn(p["moe"], h2, cfg.moe, ctx)
        x = x + y
    else:
        x = x + gated_mlp(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                          p["mlp"]["w_down"])
    return x, new_cache


def _sp_decode_attend(ctx: ParallelCtx, q, kc, vc, cache_len):
    """Sequence-parallel flash-decode: KV sharded over 'model' on seq dim."""
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import shard_map_compat as shard_map
    dp = ctx.dp_axes

    def inner(q_l, k_l, v_l, n):
        return attn.decode_attend_sp(q_l, k_l, v_l, n, axis="model")

    return shard_map(
        inner, mesh=ctx.mesh,
        in_specs=(P(dp, None, None, None), P(dp, "model", None, None),
                  P(dp, "model", None, None), P()),
        out_specs=P(dp, None, None, None), check_vma=False,
    )(q, kc, vc, cache_len)


def decode_step(cfg: ArchConfig, ctx: ParallelCtx, params: dict,
                token: jax.Array, cache: dict) -> tuple[jax.Array, dict]:
    """One decode step. token: (B, 1) int32. Returns (logits (B,1,V), cache)."""
    x = params["embed"].astype(_cdtype(params))[token]
    flags = global_layer_flags(cfg)
    cache_len = cache["len"]
    layer_caches = {k: v for k, v in cache.items() if k != "len"}

    def body(x, xs):
        layer_p, layer_c, flag = xs
        x, new_c = _decode_block(cfg, ctx, layer_p, x, layer_c, cache_len, flag)
        return x, new_c

    x, new_layer_caches = _scan(
        body, x, (params["layers"], layer_caches, flags))
    x = rms_norm(x, params["out_norm"], cfg.norm_eps)
    logits = logits_fn(cfg, params, x)
    new_cache = dict(new_layer_caches)
    new_cache["len"] = cache_len + 1
    return logits, new_cache


def prefill(cfg: ArchConfig, ctx: ParallelCtx, params: dict,
            batch: dict, max_len: int | None = None) -> tuple[jax.Array, dict]:
    """Prefill: run the full prompt, return last-position logits + cache."""
    x, _ = embed_inputs(cfg, ctx, params, batch)
    b, s = x.shape[0], x.shape[1]
    # s includes VLM patch positions; the cache must cover them too.
    max_len = max(max_len or s, s)
    positions = jnp.arange(s)[None, :]
    flags = global_layer_flags(cfg)
    cache = init_cache(cfg, b, max_len, dtype=x.dtype)

    def body(carry, xs):
        x, _aux = carry
        layer_p, flag = xs
        h = rms_norm(x, layer_p["ln1"], cfg.norm_eps)
        emit = {}
        if cfg.family == "ssm":
            y, st, cb = ssm_mod.ssm_forward(layer_p["ssm"], h, cfg.ssm,
                                            _d_inner(cfg), cfg.norm_eps,
                                            return_state=True)
            emit["ssm_state"], emit["conv_buf"] = st, cb
            return (x + y, _aux), emit
        q, k, v = attn.project_qkv(layer_p["attn"], h, positions,
                                   cfg.rope_theta, cfg.qk_norm, cfg.norm_eps)
        emit["k"], emit["v"] = k, v
        a = attn.attend(q, k, v, causal=True, window=cfg.attn_window,
                        is_global=flag)
        a = jnp.einsum("bshk,hkd->bsd", a,
                       layer_p["attn"]["wo"].astype(x.dtype))
        if cfg.family == "hybrid":
            y, st, cb = ssm_mod.ssm_forward(layer_p["ssm"], h, cfg.ssm,
                                            _d_inner(cfg), cfg.norm_eps,
                                            return_state=True)
            emit["ssm_state"], emit["conv_buf"] = st, cb
            x = x + 0.5 * (a + y)
        else:
            x = x + a
        h2 = rms_norm(x, layer_p["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            y, aux = moe_mod.moe_ffn(layer_p["moe"], h2, cfg.moe, ctx)
            x, _aux = x + y, _aux + aux
        else:
            x = x + gated_mlp(h2, layer_p["mlp"]["w_gate"],
                              layer_p["mlp"]["w_up"], layer_p["mlp"]["w_down"])
        return (x, _aux), emit

    fn = body
    if ctx.remat:
        fn = jax.checkpoint(body, policy=_remat_policy(ctx))
    (x, _), emitted = _scan(fn, (x, jnp.float32(0.0)),
                                   (params["layers"], flags))
    if "k" in emitted:
        kv_dt = cache["k"].dtype
        cache["k"] = cache["k"].at[:, :, :s].set(emitted["k"].astype(kv_dt))
        cache["v"] = cache["v"].at[:, :, :s].set(emitted["v"].astype(kv_dt))
    if "ssm_state" in emitted:
        cache["ssm_state"] = emitted["ssm_state"]
        cache["conv_buf"] = emitted["conv_buf"].astype(cache["conv_buf"].dtype)
    cache["len"] = jnp.full((), s, jnp.int32)
    logits = logits_fn(
        cfg, params, rms_norm(x[:, -1:], params["out_norm"], cfg.norm_eps))
    return logits, cache
