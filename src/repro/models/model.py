"""Unified model facade: one API over all assigned architecture families.

``Model`` wraps (family-dispatched) param construction, forward/loss,
prefill/decode, abstract input specs and logical shardings — everything the
trainer, server and dry-run need.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.dist.sharding import ParallelCtx, spec_tree_for
from repro.models import encdec, transformer
from repro.models.common import (
    abstract_params, init_params, logical_tree,
)
from repro.models.transformer import VLM_PATCH_DIM

ENC_FRAME_DIM = 1024       # stub audio frontend (w2v-BERT-style) output dim
DEC_FRACTION = 4           # encdec: S_dec = seq_len // DEC_FRACTION
VLM_NUM_PATCHES = 576      # stub vision frontend (24x24 patches, anyres base)


class Model:
    def __init__(self, cfg: ArchConfig, ctx: ParallelCtx | None = None):
        self.cfg = cfg
        self.ctx = ctx or ParallelCtx()
        self._mod = encdec if cfg.family == "encdec" else transformer

    # -- params ---------------------------------------------------------------

    def param_defs(self):
        if self.cfg.family == "encdec":
            return self._mod.param_defs(self.cfg)
        return self._mod.param_defs(
            self.cfg, getattr(self.ctx, "moe_fsdp_mode", "gather"))

    def abstract_params(self, dtype=jnp.bfloat16):
        return abstract_params(self.param_defs(), dtype)

    def param_specs(self, dtype=jnp.bfloat16):
        defs = self.param_defs()
        return spec_tree_for(logical_tree(defs), self.ctx,
                             abstract_params(defs, dtype))

    def init(self, rng: jax.Array, dtype=jnp.float32):
        return init_params(rng, self.param_defs(), dtype)

    # -- training -------------------------------------------------------------

    def loss_and_metrics(self, params, batch: dict):
        """Returns (scalar_loss, (per-sample loss, PA, PC))."""
        cfg = self.cfg
        logits, mask, aux = self._mod.forward(cfg, self.ctx, params, batch)
        labels = batch["labels"]
        if cfg.family == "vlm" and logits.shape[1] != labels.shape[1]:
            logits = logits[:, -labels.shape[1]:]          # drop patch positions
            mask = mask[:, -labels.shape[1]:]
        metrics = self._mod.per_sample_metrics(cfg, logits, labels, mask)
        loss_vec, pa, pc = metrics
        w = batch.get("weight")
        weighted = loss_vec * w if w is not None else loss_vec
        scalar = jnp.mean(weighted)
        if cfg.moe is not None:
            scalar = scalar + cfg.moe.router_aux_weight * aux
        return scalar, (loss_vec, pa, pc)

    # -- serving ----------------------------------------------------------------

    def prefill(self, params, batch: dict, max_len: int | None = None):
        return self._mod.prefill(self.cfg, self.ctx, params, batch, max_len)

    def decode_step(self, params, token, cache):
        return self._mod.decode_step(self.cfg, self.ctx, params, token, cache)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   ring: bool = False):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec.init_cache(cfg, batch, max_len,
                                     enc_len=max_len // DEC_FRACTION, dtype=dtype)
        return transformer.init_cache(cfg, batch, max_len, dtype, ring=ring)

    # -- abstract inputs for the dry-run ---------------------------------------

    def input_specs(self, shape: ShapeSpec, dtype=jnp.bfloat16) -> dict:
        """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32, b8 = jnp.int32, jnp.bool_

        def tok(bb, ss):
            return jax.ShapeDtypeStruct((bb, ss), i32)

        if shape.kind in ("train", "prefill"):
            if cfg.family == "encdec":
                batch = {
                    "frames": jax.ShapeDtypeStruct((b, s, ENC_FRAME_DIM), dtype),
                    "tokens": tok(b, s // DEC_FRACTION),
                }
            elif cfg.family == "vlm":
                batch = {
                    "patch_embeds": jax.ShapeDtypeStruct(
                        (b, VLM_NUM_PATCHES, VLM_PATCH_DIM), dtype),
                    "tokens": tok(b, s),
                }
            else:
                batch = {"tokens": tok(b, s)}
            if shape.kind == "train":
                lab = batch["tokens"].shape
                batch["labels"] = jax.ShapeDtypeStruct(lab, i32)
                batch["mask"] = jax.ShapeDtypeStruct(lab, b8)
            return batch
        # decode: one new token against a cache of length s
        ring = (cfg.attn_window is not None and s > cfg.attn_window
                and cfg.sub_quadratic)
        cache = jax.eval_shape(
            lambda: self.init_cache(b, s, dtype=dtype, ring=ring))
        return {"token": tok(b, 1), "cache": cache}

    def input_logical(self, shape: ShapeSpec) -> dict:
        """Logical sharding axes matching input_specs' structure."""
        cfg = self.cfg
        if shape.kind in ("train", "prefill"):
            out: dict[str, Any] = {"tokens": ("batch", None)}
            if cfg.family == "encdec":
                out["frames"] = ("batch", None, None)
            if cfg.family == "vlm":
                out["patch_embeds"] = ("batch", None, None)
            if shape.kind == "train":
                out["labels"] = ("batch", None)
                out["mask"] = ("batch", None)
            return out
        seq_ax = "seq_tp" if self.ctx.seq_parallel_kv else None
        cache: dict[str, Any] = {"len": ()}
        if cfg.family != "ssm" and cfg.num_heads:
            cache["k"] = (None, "batch", seq_ax, None, None)
            cache["v"] = (None, "batch", seq_ax, None, None)
        if cfg.family == "encdec":
            cache["xk"] = (None, "batch", None, None, None)
            cache["xv"] = (None, "batch", None, None, None)
        if cfg.family in ("ssm", "hybrid"):
            cache["ssm_state"] = (None, "batch", None, None, None)
            cache["conv_buf"] = (None, "batch", None, None)
        return {"token": ("batch", None), "cache": cache}

    def input_shardings(self, shape: ShapeSpec, dtype=jnp.bfloat16):
        specs = self.input_specs(shape, dtype)
        logical = self.input_logical(shape)
        return jax.tree.map(
            lambda lg, sds: self.ctx.spec(*lg, dims=tuple(sds.shape)),
            logical, specs,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))


def build_model(cfg: ArchConfig, ctx: ParallelCtx | None = None) -> Model:
    return Model(cfg, ctx)
