"""Mixture-of-Experts FFN with expert parallelism (EP over the "model" axis).

TPU-idiomatic dispatch: no ragged all-to-all. Each data shard routes its own
tokens into a capacity buffer (E, C, d) via sort-based position assignment,
every model shard computes only its local experts' slice, and one psum over
"model" combines the outputs — the standard EP combine collective.  Expert
weights are additionally FSDP-sharded over the data axes for the 1T config
and all-gathered per layer inside the scan body (ZeRO-3 style).

Runs in three modes from one code path:
  - local (mesh=None): E_loc = E, no collectives (smoke tests);
  - under shard_map over ("pod","data","model") for the distributed model.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.common import ParamDef
from repro.configs.base import MoEConfig, round_up
from repro.dist.sharding import shard_map_compat as _shard_map

from jax.sharding import PartitionSpec as P


def moe_param_defs(d_model: int, moe: MoEConfig,
                   mode: str = "gather") -> dict:
    """mode="gather": FSDP shards d_model; weights are all-gathered per layer
    (ZeRO-3).  mode="partial": FSDP shards d_ff; expert matmuls run on the
    local ff slice and the (small) expert outputs are psum'd — no weight
    gathers at all, the right trade when tokens-per-step is small (decode).
    """
    e, ff = moe.num_experts, moe.d_ff_expert
    if mode == "partial":
        return {
            "router": ParamDef((d_model, e), (None, None), scale=0.02),
            "w_gate": ParamDef((e, d_model, ff), ("exp", None, "fsdp")),
            "w_up": ParamDef((e, d_model, ff), ("exp", None, "fsdp")),
            "w_down": ParamDef((e, ff, d_model), ("exp", "fsdp", None)),
        }
    return {
        "router": ParamDef((d_model, e), (None, None), scale=0.02),
        "w_gate": ParamDef((e, d_model, ff), ("exp", "fsdp", None)),
        "w_up": ParamDef((e, d_model, ff), ("exp", "fsdp", None)),
        "w_down": ParamDef((e, ff, d_model), ("exp", None, "fsdp")),
    }


def _route_local(p: dict, x: jax.Array, moe: MoEConfig, e0: jax.Array,
                 e_loc: int, fsdp_axes: tuple[str, ...],
                 model_axis: str | None, mode: str = "gather"):
    """Core routing+compute for one device's tokens. x: (B_loc, S, d)."""
    b, s, d = x.shape
    t = b * s
    e, k = moe.num_experts, moe.top_k
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                      # (T,k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)      # renormalize

    # Load-balancing aux loss (Switch-style): E * sum_e f_e * p_e.
    f_e = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=1), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)

    # ---- capacity assignment via sort (O(Tk log Tk), tiny memory) ----------
    cap = round_up(int(moe.capacity_factor * k * t / e) + 1, 8)
    flat_e = top_e.reshape(-1)                                  # (T*k,)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))       # (E,)
    pos_sorted = jnp.arange(t * k) - seg_start[sorted_e]
    pos = jnp.zeros((t * k,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))                            # (T*k,)
    keep = pos < cap

    # ---- dispatch: scatter tokens into (E*cap, d) ---------------------------
    dst = jnp.where(keep, flat_e * cap + pos, e * cap)          # overflow slot
    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    src = jnp.repeat(xf, k, axis=0)                             # (T*k, d)
    buf = buf.at[dst].add(src)                                  # duplicates impossible
    buf = buf[: e * cap].reshape(e, cap, d)

    # ---- local expert slice --------------------------------------------------
    buf_loc = jax.lax.dynamic_slice_in_dim(buf, e0, e_loc, axis=0)
    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
    if mode == "partial" and fsdp_axes:
        # d_ff stays sharded: full-d matmuls on the local ff slice; the
        # (E_loc, C, d) ff-partials are psum'd over the fsdp axes.  Callers
        # must present IDENTICAL tokens on every fsdp shard (moe_ffn
        # all-gathers the token batch first — only sane when T is small,
        # i.e. the decode path).  Zero weight-gather traffic.
        h = jnp.einsum("ecd,edf->ecf", buf_loc, wg.astype(x.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf_loc, wu.astype(x.dtype))
        out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                         wd.astype(x.dtype))
        for ax in fsdp_axes:
            out = jax.lax.psum(out, ax)
    else:
        # ZeRO-3 gather of this layer's expert weights; innermost mesh axis
        # first so tiled concat reconstructs the (pod-major) layout.
        for ax in reversed(fsdp_axes):
            wg = jax.lax.all_gather(wg, ax, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, ax, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, ax, axis=2, tiled=True)
        h = jnp.einsum("ecd,edf->ecf", buf_loc, wg.astype(x.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf_loc, wu.astype(x.dtype))
        out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                         wd.astype(x.dtype))

    # ---- combine: gather back + weighted sum over k --------------------------
    idx = flat_e * cap + pos                                    # (T*k,) global slots
    local = (flat_e >= e0) & (flat_e < e0 + e_loc) & keep
    lidx = jnp.where(local, (flat_e - e0) * cap + pos, 0)
    vals = out.reshape(e_loc * cap, d)[lidx]
    vals = jnp.where(local[:, None], vals, 0.0)
    y = jnp.sum(
        vals.reshape(t, k, d) * top_p[..., None].astype(x.dtype), axis=1)
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)
    return y.reshape(b, s, d), aux


def moe_ffn(p: dict, x: jax.Array, moe: MoEConfig, ctx) -> tuple[jax.Array, jax.Array]:
    """MoE FFN. Returns (y, aux_loss). x: (B, S, d_model) global."""
    if ctx is None or ctx.mesh is None or ctx.tp_axis is None:
        y, aux = _route_local(p, x, moe, jnp.int32(0), moe.num_experts, (),
                              None)
        return y, aux
    mode = getattr(ctx, "moe_fsdp_mode", "gather")

    e = moe.num_experts
    tp = ctx.tp_size
    e_loc = e // tp
    assert e % tp == 0, f"{e} experts not divisible by tp={tp}"
    fsdp_axes = ctx.dp_axes if ctx.fsdp else ()
    dp = ctx.dp_axes

    def inner(p_in, x_in):
        e0 = jax.lax.axis_index("model") * e_loc
        if mode == "partial" and ctx.fsdp:
            # Decode-path EP: replicate the (tiny) token batch across the
            # data axes, compute ff-partials against the resident weight
            # shards, psum, then slice this shard's batch back out.  Trades
            # an O(T*d) token all-gather for the O(params) weight gathers.
            b_loc = x_in.shape[0]
            x_all = x_in
            for ax in reversed(dp):
                x_all = jax.lax.all_gather(x_all, ax, axis=0, tiled=True)
            y_all, aux = _route_local(p_in, x_all, moe, e0, e_loc, dp,
                                      "model", mode)
            idx = jnp.int32(0)
            for ax in dp:
                idx = idx * ctx.mesh.shape[ax] + jax.lax.axis_index(ax)
            y = jax.lax.dynamic_slice_in_dim(y_all, idx * b_loc, b_loc, 0)
            return y, aux
        y, aux = _route_local(p_in, x_in, moe, e0, e_loc, fsdp_axes, "model",
                              mode)
        # aux differs per data shard; average it so the P() out_spec is sound.
        for ax in dp:
            aux = jax.lax.pmean(aux, ax)
        return y, aux

    if mode == "partial":
        pspec = {
            "router": P(),
            "w_gate": P("model", None, dp if ctx.fsdp else None),
            "w_up": P("model", None, dp if ctx.fsdp else None),
            "w_down": P("model", dp if ctx.fsdp else None, None),
        }
    else:
        pspec = {
            "router": P(),
            "w_gate": P("model", dp if ctx.fsdp else None, None),
            "w_up": P("model", dp if ctx.fsdp else None, None),
            "w_down": P("model", None, dp if ctx.fsdp else None),
        }
    xspec = P(dp, None, None)
    y, aux = _shard_map(
        inner, mesh=ctx.mesh,
        in_specs=(pspec, xspec),
        out_specs=(xspec, P()),
        check_vma=False,
    )(p, x)
    return y, aux
