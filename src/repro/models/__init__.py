"""Pure-JAX model zoo: dense/MoE/SSM/hybrid/enc-dec/VLM LM families."""
from repro.models.model import Model, build_model  # noqa: F401
