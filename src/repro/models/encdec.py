"""Encoder-decoder backbone (seamless-m4t-v2 text/audio translation).

Per the assignment the modality frontend is a STUB: the encoder consumes
precomputed audio-frame embeddings (B, S_enc, encoder_input_dim) delivered by
``input_specs()``; everything from the first projection onward is real.
Decoder = causal self-attention + cross-attention + gated MLP, scanned.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import ParallelCtx
from repro.models import attention as attn
from repro.models.common import layer_scan as _scan
from repro.models.common import ParamDef, gated_mlp, rms_norm, stack_defs
from repro.models.transformer import token_metrics


def _remat_policy(ctx):
    if getattr(ctx, "remat_policy", "nothing") == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    return jax.checkpoint_policies.nothing_saveable


def _mlp_defs(d: int, ff: int) -> dict:
    return {
        "w_gate": ParamDef((d, ff), ("fsdp", "tp")),
        "w_up": ParamDef((d, ff), ("fsdp", "tp")),
        "w_down": ParamDef((ff, d), ("tp", "fsdp")),
    }


def param_defs(cfg: ArchConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    nq, nkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    enc_block = {
        "ln1": ParamDef((d,), (None,), init="ones"),
        "attn": attn.attn_param_defs(d, nq, nkv, dh, cfg.qk_norm),
        "ln2": ParamDef((d,), (None,), init="ones"),
        "mlp": _mlp_defs(d, cfg.d_ff),
    }
    dec_block = {
        "ln1": ParamDef((d,), (None,), init="ones"),
        "attn": attn.attn_param_defs(d, nq, nkv, dh, cfg.qk_norm),
        "lnx": ParamDef((d,), (None,), init="ones"),
        "xattn": attn.attn_param_defs(d, nq, nkv, dh, False),
        "ln2": ParamDef((d,), (None,), init="ones"),
        "mlp": _mlp_defs(d, cfg.d_ff),
    }
    return {
        "enc_in": ParamDef((cfg.encoder_input_dim, d), (None, "fsdp")),
        "enc_layers": stack_defs(enc_block, cfg.num_encoder_layers),
        "enc_norm": ParamDef((d,), (None,), init="ones"),
        "embed": ParamDef((v, d), ("tp", "fsdp"), init="embed", scale=0.02),
        "dec_layers": stack_defs(dec_block, cfg.num_layers),
        "out_norm": ParamDef((d,), (None,), init="ones"),
        "lm_head": ParamDef((d, v), ("fsdp", "tp")),
    }


def _xattn_qkv(p: dict, h_dec: jax.Array, enc_out: jax.Array, dt):
    q = jnp.einsum("bsd,dhk->bshk", h_dec, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
    return q, k, v


def _cdtype(params):
    dt = params["embed"].dtype
    return jnp.bfloat16 if dt.itemsize == 1 else dt


def encode(cfg: ArchConfig, ctx: ParallelCtx, params: dict,
           frames: jax.Array) -> jax.Array:
    dt = _cdtype(params)
    x = jnp.einsum("bse,ed->bsd", frames.astype(dt), params["enc_in"].astype(dt))
    x = ctx.cs(x, "batch", None, None)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, layer_p):
        h = rms_norm(x, layer_p["ln1"], cfg.norm_eps)
        q, k, v = attn.project_qkv(layer_p["attn"], h, positions,
                                   cfg.rope_theta, cfg.qk_norm, cfg.norm_eps)
        a = attn.attend(q, k, v, causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", a,
                           layer_p["attn"]["wo"].astype(x.dtype))
        h2 = rms_norm(x, layer_p["ln2"], cfg.norm_eps)
        x = x + gated_mlp(h2, layer_p["mlp"]["w_gate"], layer_p["mlp"]["w_up"],
                          layer_p["mlp"]["w_down"])
        return ctx.cs(x, "batch", None, None), None

    fn = body
    if ctx.remat:
        fn = jax.checkpoint(body, policy=_remat_policy(ctx))
    x, _ = _scan(fn, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _decoder_block(cfg, ctx, layer_p, x, enc_out, positions):
    h = rms_norm(x, layer_p["ln1"], cfg.norm_eps)
    q, k, v = attn.project_qkv(layer_p["attn"], h, positions, cfg.rope_theta,
                               cfg.qk_norm, cfg.norm_eps)
    a = attn.attend(q, k, v, causal=True)
    x = x + jnp.einsum("bshk,hkd->bsd", a,
                       layer_p["attn"]["wo"].astype(x.dtype))
    hx = rms_norm(x, layer_p["lnx"], cfg.norm_eps)
    qx, kx, vx = _xattn_qkv(layer_p["xattn"], hx, enc_out, x.dtype)
    ax = attn.cross_attend(qx, kx, vx)
    x = x + jnp.einsum("bshk,hkd->bsd", ax,
                       layer_p["xattn"]["wo"].astype(x.dtype))
    h2 = rms_norm(x, layer_p["ln2"], cfg.norm_eps)
    x = x + gated_mlp(h2, layer_p["mlp"]["w_gate"], layer_p["mlp"]["w_up"],
                      layer_p["mlp"]["w_down"])
    return ctx.cs(x, "batch", None, None)


def forward(cfg: ArchConfig, ctx: ParallelCtx, params: dict,
            batch: dict) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Train forward. batch: frames (B,S_enc,E), tokens (B,S_dec), mask."""
    enc_out = encode(cfg, ctx, params, batch["frames"])
    tokens = batch["tokens"]
    x = params["embed"].astype(enc_out.dtype)[tokens]
    x = ctx.cs(x, "batch", None, None)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, layer_p):
        return _decoder_block(cfg, ctx, layer_p, x, enc_out, positions), None

    fn = body
    if ctx.remat:
        fn = jax.checkpoint(body, policy=_remat_policy(ctx))
    x, _ = _scan(fn, x, params["dec_layers"])
    x = rms_norm(x, params["out_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    mask = batch.get("mask", jnp.ones(tokens.shape, bool))
    return ctx.cs(logits, "batch", None, "tp"), mask, jnp.float32(0.0)


# --- serving ---------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int, enc_len: int,
               dtype=jnp.bfloat16) -> dict:
    L = cfg.num_layers
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "len": jnp.zeros((), jnp.int32),
        "k": jnp.zeros((L, batch, max_len, hkv, dh), dtype),
        "v": jnp.zeros((L, batch, max_len, hkv, dh), dtype),
        "xk": jnp.zeros((L, batch, enc_len, hkv, dh), dtype),
        "xv": jnp.zeros((L, batch, enc_len, hkv, dh), dtype),
    }


def prefill(cfg: ArchConfig, ctx: ParallelCtx, params: dict, batch: dict,
            max_len: int | None = None) -> tuple[jax.Array, dict]:
    """Encode + precompute per-layer cross K/V + run decoder prompt."""
    enc_out = encode(cfg, ctx, params, batch["frames"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_len = max_len or s
    x = params["embed"].astype(enc_out.dtype)[tokens]
    positions = jnp.arange(s)[None, :]

    def body(x, layer_p):
        h = rms_norm(x, layer_p["ln1"], cfg.norm_eps)
        q, k, v = attn.project_qkv(layer_p["attn"], h, positions,
                                   cfg.rope_theta, cfg.qk_norm, cfg.norm_eps)
        a = attn.attend(q, k, v, causal=True)
        x = x + jnp.einsum("bshk,hkd->bsd", a,
                           layer_p["attn"]["wo"].astype(x.dtype))
        hx = rms_norm(x, layer_p["lnx"], cfg.norm_eps)
        qx, kx, vx = _xattn_qkv(layer_p["xattn"], hx, enc_out, x.dtype)
        ax = attn.cross_attend(qx, kx, vx)
        x = x + jnp.einsum("bshk,hkd->bsd", ax,
                           layer_p["xattn"]["wo"].astype(x.dtype))
        h2 = rms_norm(x, layer_p["ln2"], cfg.norm_eps)
        x = x + gated_mlp(h2, layer_p["mlp"]["w_gate"], layer_p["mlp"]["w_up"],
                          layer_p["mlp"]["w_down"])
        return x, {"k": k, "v": v, "xk": kx, "xv": vx}

    x, emitted = _scan(body, x, params["dec_layers"])
    cache = init_cache(cfg, b, max_len, enc_out.shape[1], dtype=x.dtype)
    cache["k"] = cache["k"].at[:, :, :s].set(emitted["k"].astype(cache["k"].dtype))
    cache["v"] = cache["v"].at[:, :, :s].set(emitted["v"].astype(cache["v"].dtype))
    cache["xk"] = emitted["xk"].astype(cache["xk"].dtype)
    cache["xv"] = emitted["xv"].astype(cache["xv"].dtype)
    cache["len"] = jnp.full((), s, jnp.int32)
    x = rms_norm(x[:, -1:], params["out_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, cache


def decode_step(cfg: ArchConfig, ctx: ParallelCtx, params: dict,
                token: jax.Array, cache: dict) -> tuple[jax.Array, dict]:
    dt = _cdtype(params)
    x = params["embed"].astype(dt)[token]
    cache_len = cache["len"]
    positions = cache_len[None, None] + jnp.zeros((x.shape[0], 1), jnp.int32)
    layer_caches = {k: v for k, v in cache.items() if k != "len"}

    def body(x, xs):
        layer_p, lc = xs
        h = rms_norm(x, layer_p["ln1"], cfg.norm_eps)
        q, k, v = attn.project_qkv(layer_p["attn"], h, positions,
                                   cfg.rope_theta, cfg.qk_norm, cfg.norm_eps)
        kc, vc = attn.update_cache(lc["k"], lc["v"], k.astype(lc["k"].dtype),
                                   v.astype(lc["v"].dtype), cache_len)
        a = attn.decode_attend(q, kc, vc, cache_len + 1)
        x = x + jnp.einsum("bshk,hkd->bsd", a,
                           layer_p["attn"]["wo"].astype(x.dtype))
        hx = rms_norm(x, layer_p["lnx"], cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", hx, layer_p["xattn"]["wq"].astype(x.dtype))
        ax = attn.decode_attend(qx, lc["xk"], lc["xv"],
                                jnp.full((), lc["xk"].shape[1], jnp.int32))
        x = x + jnp.einsum("bshk,hkd->bsd", ax,
                           layer_p["xattn"]["wo"].astype(x.dtype))
        h2 = rms_norm(x, layer_p["ln2"], cfg.norm_eps)
        x = x + gated_mlp(h2, layer_p["mlp"]["w_gate"], layer_p["mlp"]["w_up"],
                          layer_p["mlp"]["w_down"])
        return x, {"k": kc, "v": vc, "xk": lc["xk"], "xv": lc["xv"]}

    x, new_caches = _scan(body, x, (params["dec_layers"], layer_caches))
    x = rms_norm(x, params["out_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    new_cache = dict(new_caches)
    new_cache["len"] = cache_len + 1
    return logits, new_cache


def per_sample_metrics(cfg, logits, labels, mask, pa_threshold: float = 0.5):
    ce, correct, pmax = token_metrics(logits, labels)
    m = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(m, axis=-1), 1.0)
    loss = jnp.sum(ce * m, axis=-1) / denom
    acc = jnp.sum(correct.astype(jnp.float32) * m, axis=-1) / denom
    return loss, acc >= pa_threshold, jnp.sum(pmax * m, axis=-1) / denom
