"""Mamba2 SSD (state-space duality) block — chunked scan + recurrent decode.

Follows the minimal SSD formulation (Dao & Gu 2024, arXiv:2405.21060):
  h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t (x)    per head, state size N
  y_t = C_t . h_t + D * x_t
computed chunk-parallel: intra-chunk attention-like matmuls (MXU friendly)
plus an inter-chunk state recurrence over S/chunk steps (lax.scan).

Single B/C group (n_groups=1) as in mamba2-130m. Depthwise conv of width
``conv_width`` over (x, B, C) precedes the scan; decode keeps a ring buffer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef


def ssm_param_defs(d_model: int, ssm, d_inner: int) -> dict:
    n, nh = ssm.state_dim, d_inner // ssm.head_dim
    conv_dim = d_inner + 2 * n
    return {
        # in_proj -> z (gate, d_inner) | x (d_inner) | B (N) | C (N) | dt (nh)
        "w_in": ParamDef((d_model, 2 * d_inner + 2 * n + nh), ("fsdp", "tp")),
        "conv_w": ParamDef((ssm.conv_width, conv_dim), (None, "tp"), init="normal",
                           scale=0.5),
        "conv_b": ParamDef((conv_dim,), ("tp",), init="zeros"),
        "a_log": ParamDef((nh,), (None,), init="a_log"),
        "d_skip": ParamDef((nh,), (None,), init="ones"),
        "dt_bias": ParamDef((nh,), (None,), init="zeros"),
        "norm_w": ParamDef((d_inner,), ("tp",), init="ones"),
        "w_out": ParamDef((d_inner, d_model), ("tp", "fsdp")),
    }


def _split_in(p, x, d_inner, n, nh):
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(x.dtype))
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbcdt = xbc_dt
    xin, b, c, dt = jnp.split(xbcdt, [d_inner, d_inner + n, d_inner + 2 * n], axis=-1)
    return z, xin, b, c, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq. xbc: (B,S,C); w: (W,C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :]
        for i in range(width)
    )
    return jax.nn.silu(out + bias[None, None, :])


def ssd_scan_ref(x, dt, a_log, b, c, d_skip, chunk: int):
    """Chunked SSD. x: (B,S,NH,P); dt: (B,S,NH); b,c: (B,S,N). Returns y, final state.

    Pure-jnp oracle; the Pallas `ssd_scan` kernel implements the same math
    with VMEM-tiled chunks.  S is padded up to a chunk multiple with dt=0
    positions (identity state transition, zero contribution) so any length
    works.
    """
    B, S, NH, P = x.shape
    s_orig = S
    if S % chunk:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        # pad dt with -inf so softplus(dt)=0 -> exp(0*a)=1: identity update
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)),
                     constant_values=-1e30)
        S = S + pad
    N = b.shape[-1]
    nc = S // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))               # (NH,) negative
    dt = jax.nn.softplus(dt.astype(jnp.float32))          # (B,S,NH) > 0
    dta = dt * a[None, None, :]                           # (B,S,NH) negative

    xr = x.reshape(B, nc, chunk, NH, P)
    dtr = dt.reshape(B, nc, chunk, NH)
    dtar = dta.reshape(B, nc, chunk, NH)
    br = b.reshape(B, nc, chunk, N)
    cr = c.reshape(B, nc, chunk, N)

    cum = jnp.cumsum(dtar, axis=2)                        # (B,nc,l,NH)
    seg_total = cum[:, :, -1]                             # (B,nc,NH)

    # Intra-chunk ("diagonal block"): y_intra[t] = sum_{s<=t} C_t.B_s dt_s
    #   exp(cum_t - cum_s) x_s
    # Mask BEFORE the exp: for t < s the exponent is positive and can
    # overflow; where(mask, exp(big), 0) still back-propagates inf*0 = NaN.
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    diff = cum[:, :, :, None] - cum[:, :, None, :]             # (B,nc,t,s,NH)
    decay = jnp.exp(jnp.where(tri[None, None, :, :, None], diff, -1e30))
    cb = jnp.einsum("bctn,bcsn->bcts", cr, br)                 # (B,nc,t,s)
    scores = cb[..., None] * decay                             # (B,nc,t,s,NH)
    y_intra = jnp.einsum("bctsh,bcsh,bcshp->bcthp",
                         scores, dtr, xr.astype(jnp.float32))

    # Chunk states: state_c = sum_s exp(cum_last - cum_s) dt_s B_s x_s^T
    sdecay = jnp.exp(seg_total[:, :, None, :] - cum)           # (B,nc,s,NH)
    states = jnp.einsum("bcsh,bcsh,bcsn,bcshp->bchnp",
                        sdecay, dtr, br, xr.astype(jnp.float32))

    # Inter-chunk recurrence over nc chunks.
    def body(h, xs):
        st, seg = xs                                           # (B,NH,N,P),(B,NH)
        h_new = h * jnp.exp(seg)[:, :, None, None] + st
        return h_new, h                                        # emit state *before* chunk

    h0 = jnp.zeros((B, NH, N, P), jnp.float32)
    h_final, h_prev = jax.lax.scan(
        body, h0,
        (states.transpose(1, 0, 2, 3, 4), seg_total.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                   # (B,nc,NH,N,P)

    # Contribution of the carried-in state to each position.
    outdecay = jnp.exp(cum)                                    # (B,nc,t,NH)
    y_inter = jnp.einsum("bctn,bcth,bchnp->bcthp", cr, outdecay, h_prev)

    y = (y_intra + y_inter).reshape(B, S, NH, P)
    y = y + d_skip[None, None, :, None].astype(jnp.float32) * x.astype(jnp.float32)
    return y[:, :s_orig].astype(x.dtype), h_final


def ssm_forward(p: dict, x: jax.Array, ssm, d_inner: int,
                norm_eps: float = 1e-6, use_kernel: bool = False,
                return_state: bool = False):
    """Full-sequence SSD block forward. x: (B,S,d_model) -> (B,S,d_model).

    With ``return_state`` also returns the decode cache (final SSM state +
    conv ring buffer) so prefill can hand off to recurrent decoding.
    """
    n, nh, hd = ssm.state_dim, d_inner // ssm.head_dim, ssm.head_dim
    z, xin, b, c, dt = _split_in(p, x, d_inner, n, nh)
    xbc_pre = jnp.concatenate([xin, b, c], axis=-1)
    xbc = _causal_conv(xbc_pre, p["conv_w"].astype(x.dtype),
                       p["conv_b"].astype(x.dtype))
    xin, b, c = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    xh = xin.reshape(*xin.shape[:2], nh, hd)
    dt = dt + p["dt_bias"][None, None, :].astype(dt.dtype)
    if use_kernel:
        from repro.kernels import ops as kops
        y, h_final = kops.ssd_scan(xh, dt, p["a_log"], b, c, p["d_skip"],
                                   ssm.chunk)
    else:
        y, h_final = ssd_scan_ref(xh, dt, p["a_log"], b, c, p["d_skip"],
                                  ssm.chunk)
    y = y.reshape(*y.shape[:2], d_inner)
    y = y * jax.nn.silu(z)  # gated
    from repro.models.common import rms_norm
    y = rms_norm(y, p["norm_w"], norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(x.dtype))
    if return_state:
        conv_buf = xbc_pre[:, -(ssm.conv_width - 1):, :]
        return out, h_final, conv_buf
    return out


# ---------------------------------------------------------------------------
# Decode (recurrent) path
# ---------------------------------------------------------------------------


def ssm_init_cache(batch: int, ssm, d_inner: int, dtype=jnp.float32) -> dict:
    n, nh, hd = ssm.state_dim, d_inner // ssm.head_dim, ssm.head_dim
    conv_dim = d_inner + 2 * n
    return {
        "state": jnp.zeros((batch, nh, n, hd), jnp.float32),
        "conv_buf": jnp.zeros((batch, ssm.conv_width - 1, conv_dim), dtype),
    }


def ssm_decode_step(p: dict, x: jax.Array, cache: dict, ssm, d_inner: int,
                    norm_eps: float = 1e-6):
    """One-token recurrent update. x: (B,1,d_model)."""
    n, nh, hd = ssm.state_dim, d_inner // ssm.head_dim, ssm.head_dim
    z, xin, b, c, dt = _split_in(p, x, d_inner, n, nh)
    xbc = jnp.concatenate([xin, b, c], axis=-1)          # (B,1,conv_dim)
    window = jnp.concatenate([cache["conv_buf"], xbc], axis=1)  # (B,W,conv)
    conv_w = p["conv_w"].astype(x.dtype)
    out = jnp.einsum("bwc,wc->bc", window, conv_w) + p["conv_b"].astype(x.dtype)
    xbc1 = jax.nn.silu(out)[:, None, :]
    new_buf = window[:, 1:, :]
    xin, b, c = jnp.split(xbc1, [d_inner, d_inner + n], axis=-1)
    xh = xin.reshape(-1, nh, hd)                          # (B,NH,P)
    b1, c1 = b[:, 0], c[:, 0]                             # (B,N)
    dt1 = jax.nn.softplus(
        (dt[:, 0] + p["dt_bias"][None, :]).astype(jnp.float32))  # (B,NH)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt1 * a[None, :])                     # (B,NH)
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt1, b1.astype(jnp.float32),
                     xh.astype(jnp.float32))
    state = cache["state"] * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", c1.astype(jnp.float32), state)
    y = y + p["d_skip"][None, :, None].astype(jnp.float32) * xh.astype(jnp.float32)
    y = y.reshape(-1, 1, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    from repro.models.common import rms_norm
    y = rms_norm(y, p["norm_w"], norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(x.dtype))
    return out, {"state": state, "conv_buf": new_buf}
