"""Small conv classifier — the paper's own model family (WideResNet-flavored).

Used by the paper-reproduction benchmarks (Tables 2/5/6, Figs. 2/4) on the
synthetic easy/hard classification dataset; PA is exact top-1 correctness and
PC the max softmax probability, exactly as in the paper (Eq. 3).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, init_params


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str = "paper_cifar_cnn"
    image_size: int = 16
    channels: int = 3
    widths: tuple[int, ...] = (32, 64)
    num_classes: int = 10
    hidden: int = 128


def param_defs(cfg: CNNConfig) -> dict:
    defs = {}
    cin = cfg.channels
    for i, w in enumerate(cfg.widths):
        defs[f"conv{i}"] = ParamDef((3, 3, cin, w), (None, None, None, None),
                                    scale=(2.0 / (9 * cin)) ** 0.5)
        defs[f"convb{i}"] = ParamDef((w,), (None,), init="zeros")
        cin = w
    feat = (cfg.image_size // (2 ** len(cfg.widths))) ** 2 * cfg.widths[-1]
    defs["fc1"] = ParamDef((feat, cfg.hidden), (None, None))
    defs["fc1b"] = ParamDef((cfg.hidden,), (None,), init="zeros")
    defs["fc2"] = ParamDef((cfg.hidden, cfg.num_classes), (None, None))
    defs["fc2b"] = ParamDef((cfg.num_classes,), (None,), init="zeros")
    return defs


def init(rng: jax.Array, cfg: CNNConfig) -> dict:
    return init_params(rng, param_defs(cfg))


def forward(params: dict, cfg: CNNConfig, images: jax.Array) -> jax.Array:
    """images: (B, H, W, C) -> logits (B, num_classes)."""
    x = images
    for i in range(len(cfg.widths)):
        x = jax.lax.conv_general_dilated(
            x, params[f"conv{i}"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + params[f"convb{i}"])
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"] + params["fc1b"])
    return x @ params["fc2"] + params["fc2b"]


def per_sample_metrics(logits: jax.Array, labels: jax.Array):
    """(loss, PA, PC) per sample — paper Eq. 3 semantics."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[:, None], axis=-1)[:, 0]
    loss = lse - gold
    pa = jnp.argmax(lf, axis=-1) == labels
    pc = jnp.exp(jnp.max(lf, axis=-1) - lse)
    return loss, pa, pc
