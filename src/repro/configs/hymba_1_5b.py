"""hymba-1.5b — hybrid parallel attn+mamba heads, SWA [arXiv:2411.13676; hf].

25 attention heads (64-dim) in parallel with 25 SSM heads (d_inner=1600,
ssm_state=16); sliding-window attention (1024) with 3 global layers
(first/middle/last). Long-context decode runs all-SWA with a ring cache.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    ssm=SSMConfig(state_dim=16, head_dim=64, conv_width=4, chunk=128,
                  d_inner=1600),
    attn_window=1024, rope_theta=1e4, source="arXiv:2411.13676; hf",
)
