"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2; unverified].

Uses Adafactor (factored second moment): full Adam state for 1T params would
exceed the 16 GB/chip HBM budget at 512 chips (DESIGN.md Sec. 5).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163840, head_dim=112,
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048),
    rope_theta=1e6, source="arXiv:2501.kimi2; unverified",
    optimizer="adafactor",
)
