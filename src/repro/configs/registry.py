"""--arch <id> registry over all assigned architectures (+ paper's own)."""
from repro.configs import (
    qwen3_1_7b, smollm_135m, internlm2_20b, mistral_large_123b,
    seamless_m4t_large_v2, phi35_moe_42b, kimi_k2_1t, mamba2_130m,
    llava_next_mistral_7b, hymba_1_5b,
)
from repro.configs.base import ArchConfig, SHAPES, shape_applicable

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (qwen3_1_7b, smollm_135m, internlm2_20b, mistral_large_123b,
              seamless_m4t_large_v2, phi35_moe_42b, kimi_k2_1t, mamba2_130m,
              llava_next_mistral_7b, hymba_1_5b)
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def all_cells():
    """Every (arch, shape) pair with its applicability verdict."""
    for aname, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            ok, reason = shape_applicable(cfg, shape)
            yield cfg, shape, ok, reason
