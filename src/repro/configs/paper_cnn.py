"""The paper's own model family: small conv classifier (WideResNet-flavored)
for the KAKURENBO reproduction benchmarks on synthetic classification."""
from repro.models.cnn import CNNConfig

CONFIG = CNNConfig(name="paper-cifar-cnn", image_size=16, widths=(32, 64),
                   num_classes=10, hidden=128)
