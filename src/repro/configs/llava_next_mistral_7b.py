"""llava-next-mistral-7b — VLM, anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Vision frontend is a stub: input_specs() provides 576 precomputed 1024-dim
patch embeddings per sample (CLIP-ViT-L/14 @ 336px grid); the mm projector
and the mistral-7b text backbone are real.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000, head_dim=128,
    num_patch_tokens=576, rope_theta=1e6,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
