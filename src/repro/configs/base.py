"""Architecture + shape + run configuration schema.

Every assigned architecture is an ``ArchConfig`` in its own module under
``repro.configs``; ``repro.configs.registry`` maps ``--arch <id>`` to it.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int          # N (ssm_state)
    head_dim: int = 64      # P
    expand: int = 2         # d_inner = expand * d_model (mamba2 default)
    conv_width: int = 4
    chunk: int = 128        # SSD chunk length
    d_inner: int | None = None  # override (hybrid archs size it to heads)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int                # 0 for attn-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None   # default d_model // num_heads
    qk_norm: bool = False
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # hybrid: attention sliding window (None = full/causal)
    attn_window: int | None = None
    # encdec
    num_encoder_layers: int = 0
    encoder_input_dim: int = 0    # stub frontend embedding dim (audio frames)
    # vlm
    num_patch_tokens: int = 0     # stub frontend patch embeddings per sample
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    source: str = ""              # provenance tag from the assignment table
    optimizer: str = "adamw"      # adafactor for the 1T config (HBM budget)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        assert self.num_heads > 0
        return self.d_model // self.num_heads

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context (500K) decode/prefill is feasible."""
        return self.family == "ssm" or (
            self.family == "hybrid" and self.attn_window is not None
        )

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for rooflines."""
        d, ff, v, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd = self.resolved_head_dim if self.num_heads else 0
        n = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.num_heads:
            per_layer += d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
            per_layer += self.num_heads * hd * d
        if self.moe is not None:
            per_layer += self.moe.num_experts * 3 * d * self.moe.d_ff_expert
            per_layer += d * self.moe.num_experts  # router
        elif ff > 0:
            per_layer += 3 * d * ff  # gated MLP
        if self.ssm is not None:
            di = self.ssm.d_inner or self.ssm.expand * d
            nh = di // self.ssm.head_dim
            # in_proj -> (z, x, B, C, dt), conv over (x,B,C), out_proj.
            per_layer += d * (2 * di + 2 * self.ssm.state_dim + nh)
            per_layer += self.ssm.conv_width * (di + 2 * self.ssm.state_dim)
            per_layer += di * d + 2 * nh  # out_proj + A_log + D
        n += L * per_layer
        if self.num_encoder_layers:
            enc_layer = (d * self.num_heads * hd * 2 +
                         2 * d * self.num_kv_heads * hd + 3 * d * ff)
            n += self.num_encoder_layers * enc_layer + self.encoder_input_dim * d
        return n

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE counts only top_k experts."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        full = self.param_count()
        all_experts = L * self.moe.num_experts * 3 * d * self.moe.d_ff_expert
        active = L * self.moe.top_k * 3 * d * self.moe.d_ff_expert
        return full - all_experts + active

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        heads = 0 if self.num_heads == 0 else 4
        kv = 0 if self.num_kv_heads == 0 else 2
        return dataclasses.replace(
            self,
            num_layers=2,
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=257,
            head_dim=16 if heads else None,
            moe=None if self.moe is None else MoEConfig(
                num_experts=4, top_k=min(2, self.moe.top_k), d_ff_expert=64),
            ssm=None if self.ssm is None else dataclasses.replace(
                self.ssm, state_dim=min(self.ssm.state_dim, 16), head_dim=16,
                chunk=16, d_inner=64 if self.ssm.d_inner else None),
            attn_window=None if self.attn_window is None else 32,
            num_encoder_layers=2 if self.num_encoder_layers else 0,
            encoder_input_dim=32 if self.encoder_input_dim else 0,
            num_patch_tokens=8 if self.num_patch_tokens else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) per the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "SKIP(full-attn): 512K ctx needs sub-quadratic attention"
    return True, ""


def tokens_per_step(shape: ShapeSpec) -> int:
    if shape.kind == "train":
        return shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return shape.seq_len * shape.global_batch
    return shape.global_batch  # decode: one new token per sequence


def round_up(x: int, m: int) -> int:
    return int(math.ceil(x / m) * m)
