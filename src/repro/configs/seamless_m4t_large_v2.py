"""seamless-m4t-large-v2 — enc-dec audio/text [arXiv:2308.11596; hf].

Modality frontend is a stub: input_specs() provides precomputed 1024-dim
frame embeddings (w2v-BERT-style); encoder/decoder backbones are real.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=256206, head_dim=64,
    num_encoder_layers=24, encoder_input_dim=1024,
    rope_theta=1e4, source="arXiv:2308.11596; hf",
)
