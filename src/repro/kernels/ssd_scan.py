"""Mamba2 SSD chunked-scan Pallas kernel.

Grid (B*NH, S/chunk); the chunk axis is sequential so the inter-chunk SSM
state (N x P, f32) persists in VMEM scratch — the kernel streams chunks of
x/dt/dta/B/C through VMEM, does the three intra-chunk matmuls on the MXU and
carries the recurrence without ever spilling the state to HBM (the GPU
implementation materializes per-chunk states; on TPU the sequential-grid
scratch pattern removes that HBM round-trip entirely — DESIGN.md Sec. 2).

Inputs are pre-arranged by ops.py:
  x   (B*NH, S, P)     head inputs
  dt  (B*NH, S)        softplus'd step sizes (>0)
  dta (B*NH, S)        dt * a  (negative decay exponents)
  b,c (B*NH, S, N)     input/output projections (group-broadcast per head)
Outputs: y (B*NH, S, P), final state (B*NH, N, P) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend


def _kernel(x_ref, dt_ref, dta_ref, b_ref, c_ref, y_ref, state_out_ref,
            state_ref, *, chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)        # (chunk, P)
    dt = dt_ref[0].astype(jnp.float32)      # (chunk,)
    dta = dta_ref[0].astype(jnp.float32)    # (chunk,)
    bm = b_ref[0].astype(jnp.float32)       # (chunk, N)
    cm = c_ref[0].astype(jnp.float32)       # (chunk, N)

    cum = jnp.cumsum(dta)                   # (chunk,)
    seg = cum[-1]

    # intra-chunk: scores[t,s] = (c_t . b_s) * exp(cum_t - cum_s) * dt_s, t>=s
    cb = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    # mask the exponent (not the product): t<s entries would overflow exp
    decay = jnp.exp(jnp.where(tri, cum[:, None] - cum[None, :], -1e30))
    scores = cb * decay * dt[None, :]
    y = jnp.dot(scores, x, preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    state = state_ref[...]                  # (N, P)
    y = y + jnp.dot(cm * jnp.exp(cum)[:, None], state,
                    preferred_element_type=jnp.float32)

    # state update: state = state*exp(seg) + sum_s exp(seg-cum_s) dt_s b_s x_s^T
    w = (jnp.exp(seg - cum) * dt)[:, None] * bm      # (chunk, N)
    state_ref[...] = state * jnp.exp(seg) + jnp.dot(
        w.T, x, preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == pl.num_programs(1) - 1)
    def _final():
        state_out_ref[0] = state_ref[...]


def ssd_scan_kernel(x: jax.Array, dt: jax.Array, dta: jax.Array,
                    b: jax.Array, c: jax.Array, chunk: int = 128,
                    interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """x: (BH, S, P); dt/dta: (BH, S); b/c: (BH, S, N)."""
    interpret = backend.resolve(interpret)
    bh, s, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    grid = (bh, s // chunk)
    y, state = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, n, p), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, dta, b, c)
    return y, state
