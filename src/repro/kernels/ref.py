"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_scan_ref  # noqa: F401  (shared oracle)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """q: (B,S,Hq,D); k,v: (B,S,Hkv,D) with Hq %% Hkv == 0. f32 softmax."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    q5 = q.reshape(b, s, hkv, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q5, k,
                        preferred_element_type=jnp.float32) * d ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v).reshape(b, s, hq, d)


def loss_confidence_ref(logits: jax.Array, labels: jax.Array):
    """(T, V) logits, (T,) labels -> per-token (ce, correct, pmax) in f32.

    The fused KAKURENBO bookkeeping: cross-entropy loss, prediction accuracy
    and prediction confidence (max softmax prob, paper Eq. 3) in one pass.
    """
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(lf - m[:, None]), axis=-1))
    gold = jnp.take_along_axis(lf, labels[:, None], axis=-1)[:, 0]
    ce = lse - gold
    correct = jnp.argmax(lf, axis=-1) == labels
    pmax = jnp.exp(m - lse)
    return ce, correct, pmax


def histogram_ref(loss: jax.Array, valid: jax.Array, lo: jax.Array,
                  hi: jax.Array, bins: int) -> jax.Array:
    """(N,) losses -> (bins,) i32 histogram over [lo, hi] (clipped)."""
    span = jnp.maximum(hi - lo, 1e-12)
    idx = jnp.clip(((loss - lo) / span * bins).astype(jnp.int32), 0, bins - 1)
    return jnp.zeros((bins,), jnp.int32).at[idx].add(valid.astype(jnp.int32))


def minmax_ref(loss: jax.Array, valid: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Raw (lo, hi) of the valid losses; [BIG, -BIG] when none are valid."""
    from repro.kernels.threshold_select import BIG
    lo = jnp.min(jnp.where(valid, loss, jnp.float32(BIG)))
    hi = jnp.max(jnp.where(valid, loss, jnp.float32(-BIG)))
    return lo, hi
