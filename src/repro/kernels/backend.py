"""The single Pallas backend probe: compiled Mosaic vs interpret mode.

Every kernel module used to hardcode ``interpret: bool = True`` per
function, which meant a real TPU deployment had to edit four files (or
monkeypatch ``ops.INTERPRET``) before anything compiled.  All kernel entry
points now default ``interpret=None`` and resolve through this one probe:

- ``REPRO_PALLAS_INTERPRET=0|1`` (env) overrides everything — a TPU run
  compiles without code edits, and a TPU *parity* run can still force the
  interpreter;
- otherwise interpret mode is chosen exactly when the default jax backend
  is not a TPU (this CPU container, CI) — the only platform where the
  Mosaic lowering exists.

The probe result is cached for the life of the process (jax's backend
choice is fixed once initialised).  Tests that monkeypatch the env var must
call ``probe_cache_clear()``.

``scoring_backend()`` is the hot-path variant of the same decision: the
fused (ce, pa, pc) scoring inside the train step should run the Pallas
kernel only where it compiles ("kernel"); under the interpreter it would be
orders of magnitude slower than XLA, so the hot path falls back to the
fused one-pass jnp reference ("reference") — the interpreted kernel stays
reachable explicitly, for the parity suites.
"""
from __future__ import annotations

import functools
import os

import jax

#: Env override: "0"/"false" compiles the kernels, anything truthy forces
#: interpret mode. Unset = probe the jax backend.
ENV_VAR = "REPRO_PALLAS_INTERPRET"

_FALSY = ("0", "false", "no", "off", "")


@functools.lru_cache(maxsize=None)
def use_interpret() -> bool:
    """True when Pallas kernels should run in interpret mode."""
    env = os.environ.get(ENV_VAR)
    if env is not None:
        return env.strip().lower() not in _FALSY
    return jax.default_backend() != "tpu"


def resolve(interpret: bool | None) -> bool:
    """The per-call ``interpret=`` default: explicit wins, else the probe."""
    return use_interpret() if interpret is None else bool(interpret)


def backend_name() -> str:
    """"interpret" or "pallas" — the label BENCH records carry."""
    return "interpret" if use_interpret() else "pallas"


def scoring_backend() -> str:
    """Hot-path dispatch for the fused scoring: "kernel" where the Pallas
    kernel compiles, "reference" (fused one-pass jnp) under the interpreter."""
    return "reference" if use_interpret() else "kernel"


def probe_cache_clear() -> None:
    """Forget the cached probe (tests that flip ``REPRO_PALLAS_INTERPRET``)."""
    use_interpret.cache_clear()
