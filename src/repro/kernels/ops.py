"""Jit'd public wrappers over the Pallas kernels (padding, layout, dispatch).

Backend selection is centralised in ``repro.kernels.backend``: every wrapper
takes ``interpret: bool | None = None`` and resolves ``None`` through the
probe (interpret mode off-TPU, compiled Mosaic on TPU, both overridable with
``REPRO_PALLAS_INTERPRET=0|1``) — resolution happens *outside* the jit
boundary and the flag is a static argument, so flipping the backend
retraces instead of silently reusing a stale compilation.

``fused_loss_metrics`` is the train-hot-path entry point: the per-sample
(ce, PA, PC) triple of paper Sec. 3.4 in one streaming pass, differentiable
(an analytic ``custom_vjp`` — ``pallas_call`` has no autodiff rule), with
the forward dispatched per ``backend.scoring_backend()``: the Pallas kernel
where it compiles, a fused one-pass jnp twin where the kernel would only
interpret.  ``rank_select`` is the count-then-select twin for the rank-based
plans (see ``threshold_select.rank_select_mask``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import backend
from repro.kernels import flash_attention as _fa
from repro.kernels import loss_confidence as _lc
from repro.kernels import ssd_scan as _ssd
from repro.kernels import threshold_select as _ts

# Re-exported probe API (the documented entry points).
use_interpret = backend.use_interpret
backend_name = backend.backend_name
scoring_backend = backend.scoring_backend


@functools.partial(jax.jit,
                   static_argnames=("causal", "blk_q", "blk_k", "interpret"))
def flash_attention(q, k, v, causal: bool = True, blk_q: int = 128,
                    blk_k: int = 128, interpret: bool | None = None):
    return _fa.flash_attention(q, k, v, causal=causal, blk_q=blk_q,
                               blk_k=blk_k, interpret=backend.resolve(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_log, b, c, d_skip, chunk: int = 128,
             interpret: bool | None = None):
    """Same signature as models.ssm.ssd_scan_ref (the oracle).

    x: (B,S,NH,P); dt: (B,S,NH) raw (pre-softplus); b,c: (B,S,N).
    """
    interpret = backend.resolve(interpret)
    B, S, NH, P = x.shape
    n = b.shape[-1]
    s_orig = S
    if S % chunk:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        S += pad
    a = -jnp.exp(a_log.astype(jnp.float32))
    dtp = jax.nn.softplus(dt.astype(jnp.float32))            # (B,S,NH)
    dta = dtp * a[None, None, :]
    # (B*NH, ...) layout, b/c broadcast across heads
    xr = x.transpose(0, 2, 1, 3).reshape(B * NH, S, P)
    dtr = dtp.transpose(0, 2, 1).reshape(B * NH, S)
    dtar = dta.transpose(0, 2, 1).reshape(B * NH, S)
    br = jnp.broadcast_to(b[:, None], (B, NH, S, n)).reshape(B * NH, S, n)
    cr = jnp.broadcast_to(c[:, None], (B, NH, S, n)).reshape(B * NH, S, n)
    y, state = _ssd.ssd_scan_kernel(xr, dtr, dtar, br, cr, chunk=chunk,
                                    interpret=interpret)
    y = y.reshape(B, NH, S, P).transpose(0, 2, 1, 3)[:, :s_orig]
    y = y + d_skip[None, None, :, None].astype(jnp.float32) * x[:, :s_orig].astype(jnp.float32)
    state = state.reshape(B, NH, n, P)
    return y.astype(x.dtype), state


def _padded_kernel_metrics(lf, lab, interpret):
    """Pad (T, V) to the kernel's block grid and run loss_confidence_kernel."""
    t = lf.shape[0]
    v = lf.shape[1]
    blk_t = 256
    if t % blk_t:
        pad = blk_t - t % blk_t
        lf = jnp.pad(lf, ((0, pad), (0, 0)))
        lab = jnp.pad(lab, (0, pad))
    blk_v = 2048
    while v % blk_v:
        blk_v //= 2
    ce, cor, pmax = _lc.loss_confidence_kernel(
        lf, lab, blk_t=min(blk_t, lf.shape[0]), blk_v=max(blk_v, 1),
        interpret=interpret)
    return ce[:t], cor[:t], pmax[:t]


@functools.partial(jax.jit, static_argnames=("interpret",))
def loss_confidence(logits, labels, interpret: bool | None = None):
    """(..., V) logits + (...) labels -> per-element (ce, correct, pmax)."""
    interpret = backend.resolve(interpret)
    shape = labels.shape
    v = logits.shape[-1]
    lf = logits.reshape(-1, v)
    lab = labels.reshape(-1)
    t = lf.shape[0]
    ce, cor, pmax = _padded_kernel_metrics(lf, lab, interpret)
    return (ce.reshape(shape), cor.reshape(shape).astype(bool),
            pmax.reshape(shape))


def _pad_masked(loss, valid, blk: int = 2048):
    """Pad to a blk multiple with valid=0 entries (invisible to the masked
    reductions), so any N drives the fixed-block kernels."""
    n = loss.shape[0]
    if n % blk:
        pad = blk - n % blk
        loss = jnp.pad(loss, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    return loss, valid, min(blk, loss.shape[0])


@functools.partial(jax.jit, static_argnames=("bins", "interpret"))
def loss_histogram(loss, valid, lo, hi, bins: int = 512,
                   interpret: bool | None = None):
    loss, valid, blk = _pad_masked(loss, valid)
    return _ts.histogram_kernel(loss, valid, lo, hi, bins=bins, blk_n=blk,
                                interpret=backend.resolve(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def loss_minmax(loss, valid, interpret: bool | None = None):
    """Raw (lo, hi) scalars of the valid losses (no degeneracy fold — see
    threshold_select.minmax_kernel)."""
    loss, valid, blk = _pad_masked(loss, valid)
    mm = _ts.minmax_kernel(loss, valid, blk_n=blk,
                           interpret=backend.resolve(interpret))
    return mm[0], mm[1]


@functools.partial(jax.jit,
                   static_argnames=("high", "use_kernel", "interpret"))
def rank_select(scores, k, high: bool = False, use_kernel: bool | None = None,
                interpret: bool | None = None):
    """Exact k-smallest (or -largest) mask via count-then-select.

    Bit-identical to the stable-argsort rank masks (see
    threshold_select.rank_select_mask for the tie contract).  ``use_kernel``
    defaults per the probe, mirroring ``scoring_backend()``: the Pallas
    histogram/select kernels where they compile (TPU), the jnp radix twin
    under the interpreter — either way the plan stops materialising a full
    argsort.
    """
    if use_kernel is None:
        use_kernel = not backend.use_interpret()
    return _ts.rank_select_mask(scores, k, high=high, use_kernel=use_kernel,
                                interpret=backend.resolve(interpret))


# ---------------------------------------------------------------------------
# Fused in-step scoring: differentiable (ce, pa, pc) in one streaming pass
# ---------------------------------------------------------------------------


def _reference_metrics(lf, lab):
    """Fused one-pass jnp twin of loss_confidence_kernel (the hot-path
    backend where the kernel would only interpret): two reductions (max,
    sum-exp) + the gold gather — no separate argmax/logsumexp/softmax
    passes, and ``correct`` falls out of the same max (gold >= m, exactly
    the kernel's tie rule)."""
    m = jnp.max(lf, axis=-1)
    sumexp = jnp.sum(jnp.exp(lf - m[:, None]), axis=-1)
    lse = m + jnp.log(sumexp)
    gold = jnp.take_along_axis(lf, lab[:, None], axis=-1)[:, 0]
    ce = lse - gold
    correct = gold >= m
    pmax = 1.0 / sumexp
    return ce, correct, pmax


@functools.lru_cache(maxsize=None)
def _fused_metrics_vjp(which: str, interpret: bool):
    """The custom_vjp core, cached per (backend, interpret) pair.

    Forward runs the one-pass scoring (kernel or jnp reference); backward is
    the analytic softmax gradient — ``pallas_call`` has no autodiff rule,
    and even the jnp path profits: lse is reconstructed from the saved
    ``ce`` (lse = ce + gold) instead of re-reducing, so the backward is a
    single elementwise pass over the logits.  Only ``ce`` carries gradient;
    PA/PC are selection bookkeeping, not loss terms.
    """

    @jax.custom_vjp
    def fused(logits, labels):
        lf = logits.astype(jnp.float32)
        if which == "kernel":
            ce, cor, pmax = _padded_kernel_metrics(lf, labels, interpret)
            return ce, cor != 0, pmax
        return _reference_metrics(lf, labels)

    def fwd(logits, labels):
        out = fused(logits, labels)
        return out, (logits, labels, out[0])

    def bwd(res, cts):
        logits, labels, ce = res
        g = cts[0]            # d/d(ce); PA/PC cotangents are float0: ignored
        lf = logits.astype(jnp.float32)
        gold = jnp.take_along_axis(lf, labels[:, None], axis=-1)[:, 0]
        lse = ce + gold       # saved forward result: no second reduction
        probs = jnp.exp(lf - lse[:, None])
        onehot = labels[:, None] == jax.lax.broadcasted_iota(
            labels.dtype, lf.shape, 1)
        dlogits = ((probs - onehot) * g[:, None]).astype(logits.dtype)
        zeros = np.zeros(labels.shape, dtype=jax.dtypes.float0)
        return dlogits, zeros

    fused.defvjp(fwd, bwd)
    return fused


def fused_loss_metrics(logits, labels, scoring: str | None = None,
                       interpret: bool | None = None):
    """Per-sample ``(ce, pa, pc)`` from (B, V) logits in one fused pass.

    The train-step scoring behind ``TrainConfig.fused_scoring``: one
    streaming online-softmax pass instead of the three jnp reductions of
    ``models.cnn.per_sample_metrics``, differentiable through ``ce`` (the
    analytic vjp above).  ``scoring`` picks the forward backend — "kernel"
    (Pallas) or "reference" (fused jnp) — defaulting to
    ``backend.scoring_backend()``: the kernel wherever it compiles, the
    reference where the kernel would only interpret.
    """
    scoring = scoring or backend.scoring_backend()
    if scoring not in ("kernel", "reference"):
        raise ValueError(
            f"fused_loss_metrics scoring={scoring!r}: must be 'kernel' or "
            "'reference'")
    return _fused_metrics_vjp(scoring, backend.resolve(interpret))(
        logits, labels)
