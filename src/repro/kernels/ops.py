"""Jit'd public wrappers over the Pallas kernels (padding, layout, dispatch).

``interpret`` defaults to True because this container is CPU-only; on a real
TPU deployment set ``repro.kernels.ops.INTERPRET = False`` (or pass
explicitly) and the same code lowers through Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import loss_confidence as _lc
from repro.kernels import ssd_scan as _ssd
from repro.kernels import threshold_select as _ts

INTERPRET = True


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k"))
def flash_attention(q, k, v, causal: bool = True, blk_q: int = 128,
                    blk_k: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, blk_q=blk_q,
                               blk_k=blk_k, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, a_log, b, c, d_skip, chunk: int = 128):
    """Same signature as models.ssm.ssd_scan_ref (the oracle).

    x: (B,S,NH,P); dt: (B,S,NH) raw (pre-softplus); b,c: (B,S,N).
    """
    B, S, NH, P = x.shape
    n = b.shape[-1]
    s_orig = S
    if S % chunk:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        S += pad
    a = -jnp.exp(a_log.astype(jnp.float32))
    dtp = jax.nn.softplus(dt.astype(jnp.float32))            # (B,S,NH)
    dta = dtp * a[None, None, :]
    # (B*NH, ...) layout, b/c broadcast across heads
    xr = x.transpose(0, 2, 1, 3).reshape(B * NH, S, P)
    dtr = dtp.transpose(0, 2, 1).reshape(B * NH, S)
    dtar = dta.transpose(0, 2, 1).reshape(B * NH, S)
    br = jnp.broadcast_to(b[:, None], (B, NH, S, n)).reshape(B * NH, S, n)
    cr = jnp.broadcast_to(c[:, None], (B, NH, S, n)).reshape(B * NH, S, n)
    y, state = _ssd.ssd_scan_kernel(xr, dtr, dtar, br, cr, chunk=chunk,
                                    interpret=INTERPRET)
    y = y.reshape(B, NH, S, P).transpose(0, 2, 1, 3)[:, :s_orig]
    y = y + d_skip[None, None, :, None].astype(jnp.float32) * x[:, :s_orig].astype(jnp.float32)
    state = state.reshape(B, NH, n, P)
    return y.astype(x.dtype), state


@jax.jit
def loss_confidence(logits, labels):
    """(..., V) logits + (...) labels -> per-element (ce, correct, pmax)."""
    shape = labels.shape
    v = logits.shape[-1]
    lf = logits.reshape(-1, v)
    lab = labels.reshape(-1)
    t = lf.shape[0]
    blk_t = 256
    if t % blk_t:
        pad = blk_t - t % blk_t
        lf = jnp.pad(lf, ((0, pad), (0, 0)))
        lab = jnp.pad(lab, (0, pad))
    blk_v = 2048
    while v % blk_v:
        blk_v //= 2
    ce, cor, pmax = _lc.loss_confidence_kernel(
        lf, lab, blk_t=min(blk_t, lf.shape[0]), blk_v=max(blk_v, 1),
        interpret=INTERPRET)
    return (ce[:t].reshape(shape), cor[:t].reshape(shape).astype(bool),
            pmax[:t].reshape(shape))


def _pad_masked(loss, valid, blk: int = 2048):
    """Pad to a blk multiple with valid=0 entries (invisible to the masked
    reductions), so any N drives the fixed-block kernels."""
    n = loss.shape[0]
    if n % blk:
        pad = blk - n % blk
        loss = jnp.pad(loss, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    return loss, valid, min(blk, loss.shape[0])


@functools.partial(jax.jit, static_argnames=("bins",))
def loss_histogram(loss, valid, lo, hi, bins: int = 512):
    loss, valid, blk = _pad_masked(loss, valid)
    return _ts.histogram_kernel(loss, valid, lo, hi, bins=bins, blk_n=blk,
                                interpret=INTERPRET)


@jax.jit
def loss_minmax(loss, valid):
    """Raw (lo, hi) scalars of the valid losses (no degeneracy fold — see
    threshold_select.minmax_kernel)."""
    loss, valid, blk = _pad_masked(loss, valid)
    mm = _ts.minmax_kernel(loss, valid, blk_n=blk, interpret=INTERPRET)
    return mm[0], mm[1]
