"""Fused CE + prediction-accuracy + prediction-confidence Pallas kernel.

KAKURENBO needs (loss, PA, PC) per sample every step (paper Sec. 3.4 — the
"lagging loss" is harvested from the training forward pass).  Done naively on
LM logits this is three separate passes over a (tokens x 152K-vocab) tensor;
this kernel computes all three in ONE streaming pass with an online-softmax
recurrence over vocab tiles: the paper's bookkeeping becomes bandwidth-free
relative to the loss computation it was already doing.

Grid (T/blk_t, V/blk_v), vocab sequential; scratch carries running max m,
running sum-of-exp l (rescaled on max updates) and the gold-label logit.
Outputs per token: ce = lse - gold, correct = (gold == max), pmax = 1/l_final
(since pmax = exp(m - lse) = 1/sum exp(x - m)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend

NEG_INF = -1e30


def _kernel(x_ref, lab_ref, ce_ref, cor_ref, pmax_ref, m_ref, l_ref, g_ref,
            *, blk_v: int):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        g_ref[...] = jnp.full_like(g_ref, NEG_INF)

    x = x_ref[...].astype(jnp.float32)          # (blk_t, blk_v)
    lab = lab_ref[...]                          # (blk_t,)
    v0 = iv * blk_v

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(x, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    l_new = (l_prev * jnp.exp(m_prev - m_new)
             + jnp.sum(jnp.exp(x - m_new[:, None]), axis=-1))
    cols = v0 + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    gold_blk = jnp.max(jnp.where(cols == lab[:, None], x, NEG_INF), axis=-1)
    m_ref[...] = m_new
    l_ref[...] = l_new
    g_ref[...] = jnp.maximum(g_ref[...], gold_blk)

    @pl.when(iv == pl.num_programs(1) - 1)
    def _final():
        m, l, g = m_ref[...], l_ref[...], g_ref[...]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        ce_ref[...] = lse - g
        cor_ref[...] = (g >= m).astype(jnp.int32)
        pmax_ref[...] = 1.0 / jnp.maximum(l, 1e-30)


def loss_confidence_kernel(logits: jax.Array, labels: jax.Array,
                           blk_t: int = 256, blk_v: int = 2048,
                           interpret: bool | None = None):
    """logits: (T, V); labels: (T,). Returns (ce, correct_i32, pmax) f32/(T,)."""
    interpret = backend.resolve(interpret)
    t, v = logits.shape
    blk_t = min(blk_t, t)
    blk_v = min(blk_v, v)
    assert t % blk_t == 0 and v % blk_v == 0, (t, v, blk_t, blk_v)
    grid = (t // blk_t, v // blk_v)
    ce, cor, pmax = pl.pallas_call(
        functools.partial(_kernel, blk_v=blk_v),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_t, blk_v), lambda it, iv: (it, iv)),
            pl.BlockSpec((blk_t,), lambda it, iv: (it,)),
        ],
        out_specs=[
            pl.BlockSpec((blk_t,), lambda it, iv: (it,)),
            pl.BlockSpec((blk_t,), lambda it, iv: (it,)),
            pl.BlockSpec((blk_t,), lambda it, iv: (it,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t,), jnp.float32),
            jax.ShapeDtypeStruct((t,), jnp.int32),
            jax.ShapeDtypeStruct((t,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((blk_t,), jnp.float32),
            pltpu.VMEM((blk_t,), jnp.float32),
            pltpu.VMEM((blk_t,), jnp.float32),
        ],
        interpret=interpret,
    )(logits, labels)
    return ce, cor, pmax
