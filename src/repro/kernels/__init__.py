"""Pallas TPU kernels (validated in interpret mode on CPU) + jnp oracles."""
from repro.kernels import ops  # noqa: F401
