"""Loss-histogram Pallas kernel for O(N) hidden-sample selection.

The paper's selection sorts all N lagging losses (O(N log N), its own listed
bottleneck in Table 1).  The optimized selection replaces the sort with a
fixed 512-bin histogram + CDF threshold (core/selection.py); this kernel
computes the local histogram in one streaming pass: loss tiles land in VMEM,
are binned via a one-hot iota compare (VPU) and reduced into a persistent
(bins,) scratch accumulator across the sequential grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(loss_ref, valid_ref, range_ref, hist_ref, acc_ref, *, bins: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lo, hi = range_ref[0], range_ref[1]
    span = jnp.maximum(hi - lo, 1e-12)
    x = loss_ref[...].astype(jnp.float32)            # (blk_n,)
    valid = valid_ref[...] != 0                      # (blk_n,)
    idx = jnp.clip(((x - lo) / span * bins).astype(jnp.int32), 0, bins - 1)
    # one-hot accumulate: (blk_n, bins) compare + column sum (VPU-friendly;
    # no scatter needed, which TPU vector memory dislikes)
    onehot = (idx[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (x.shape[0], bins), 1))
    onehot = jnp.where(valid[:, None], onehot, False)
    acc_ref[...] += jnp.sum(onehot.astype(jnp.int32), axis=0)

    @pl.when(i == pl.num_programs(0) - 1)
    def _final():
        hist_ref[...] = acc_ref[...]


def histogram_kernel(loss: jax.Array, valid: jax.Array, lo: jax.Array,
                     hi: jax.Array, bins: int = 512, blk_n: int = 2048,
                     interpret: bool = True) -> jax.Array:
    """loss: (N,) f32; valid: (N,) bool/int. Returns (bins,) i32 histogram."""
    n = loss.shape[0]
    blk_n = min(blk_n, n)
    assert n % blk_n == 0, (n, blk_n)
    rng = jnp.stack([jnp.asarray(lo, jnp.float32), jnp.asarray(hi, jnp.float32)])
    return pl.pallas_call(
        functools.partial(_kernel, bins=bins),
        grid=(n // blk_n,),
        in_specs=[
            pl.BlockSpec((blk_n,), lambda i: (i,)),
            pl.BlockSpec((blk_n,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((bins,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bins,), jnp.int32)],
        interpret=interpret,
    )(loss, valid.astype(jnp.int32), rng)
