"""Loss-histogram Pallas kernels for O(N) hidden-sample selection.

The paper's selection sorts all N lagging losses (O(N log N), its own listed
bottleneck in Table 1).  The optimized selection replaces the sort with a
fixed 512-bin histogram + CDF threshold (core/selection.py, method
``"histogram_pallas"``).  Two streaming passes over the losses:

1. ``minmax_kernel`` — the range pass: per-tile masked min/max reduced into
   a persistent 2-scalar SMEM accumulator, yielding the raw (lo, hi) bin
   range of the valid losses.
2. ``histogram_kernel`` — loss tiles land in VMEM, are binned via a one-hot
   iota compare (VPU) and reduced into a persistent (bins,) scratch
   accumulator across the sequential grid.

Both return *raw* local reductions so a sharded caller can psum/pmin/pmax
them before deriving the CDF threshold (see select_hidden_histogram).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Sentinel for masked min/max: finite so f32 arithmetic on it stays exact
# and (lo - hi) on an all-invalid input does not produce inf/nan.
BIG = 3.4e38


def _kernel(loss_ref, valid_ref, range_ref, hist_ref, acc_ref, *, bins: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lo, hi = range_ref[0], range_ref[1]
    span = jnp.maximum(hi - lo, 1e-12)
    x = loss_ref[...].astype(jnp.float32)            # (blk_n,)
    valid = valid_ref[...] != 0                      # (blk_n,)
    idx = jnp.clip(((x - lo) / span * bins).astype(jnp.int32), 0, bins - 1)
    # one-hot accumulate: (blk_n, bins) compare + column sum (VPU-friendly;
    # no scatter needed, which TPU vector memory dislikes)
    onehot = (idx[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (x.shape[0], bins), 1))
    onehot = jnp.where(valid[:, None], onehot, False)
    acc_ref[...] += jnp.sum(onehot.astype(jnp.int32), axis=0)

    @pl.when(i == pl.num_programs(0) - 1)
    def _final():
        hist_ref[...] = acc_ref[...]


def histogram_kernel(loss: jax.Array, valid: jax.Array, lo: jax.Array,
                     hi: jax.Array, bins: int = 512, blk_n: int = 2048,
                     interpret: bool = True) -> jax.Array:
    """loss: (N,) f32; valid: (N,) bool/int. Returns (bins,) i32 histogram."""
    n = loss.shape[0]
    blk_n = min(blk_n, n)
    assert n % blk_n == 0, (n, blk_n)
    rng = jnp.stack([jnp.asarray(lo, jnp.float32), jnp.asarray(hi, jnp.float32)])
    return pl.pallas_call(
        functools.partial(_kernel, bins=bins),
        grid=(n // blk_n,),
        in_specs=[
            pl.BlockSpec((blk_n,), lambda i: (i,)),
            pl.BlockSpec((blk_n,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((bins,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bins,), jnp.int32)],
        interpret=interpret,
    )(loss, valid.astype(jnp.int32), rng)


def _minmax_kernel(loss_ref, valid_ref, out_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[0] = jnp.float32(BIG)
        acc_ref[1] = jnp.float32(-BIG)

    x = loss_ref[...].astype(jnp.float32)            # (blk_n,)
    valid = valid_ref[...] != 0                      # (blk_n,)
    acc_ref[0] = jnp.minimum(acc_ref[0], jnp.min(jnp.where(valid, x, BIG)))
    acc_ref[1] = jnp.maximum(acc_ref[1], jnp.max(jnp.where(valid, x, -BIG)))

    @pl.when(i == pl.num_programs(0) - 1)
    def _final():
        out_ref[0] = acc_ref[0]
        out_ref[1] = acc_ref[1]


def minmax_kernel(loss: jax.Array, valid: jax.Array, blk_n: int = 2048,
                  interpret: bool = True) -> jax.Array:
    """Range pass: (N,) loss + valid mask -> (2,) f32 raw [lo, hi].

    Raw means no degeneracy fold: an all-invalid input yields
    [BIG, -BIG], which the caller collapses (lo = min(lo, hi)) *after* any
    cross-shard pmin/pmax so sharded and single-device results agree.
    """
    n = loss.shape[0]
    blk_n = min(blk_n, n)
    assert n % blk_n == 0, (n, blk_n)
    return pl.pallas_call(
        _minmax_kernel,
        grid=(n // blk_n,),
        in_specs=[
            pl.BlockSpec((blk_n,), lambda i: (i,)),
            pl.BlockSpec((blk_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.float32),
        scratch_shapes=[pltpu.SMEM((2,), jnp.float32)],
        interpret=interpret,
    )(loss, valid.astype(jnp.int32))


def histogram_with_range(loss: jax.Array, valid: jax.Array, bins: int = 512,
                         blk_n: int = 2048, interpret: bool = True
                         ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused two-pass selection front end: (hist, lo_raw, hi_raw).

    The range pass feeds the histogram pass on device; nothing crosses the
    host boundary.
    """
    mm = minmax_kernel(loss, valid, blk_n=blk_n, interpret=interpret)
    lo_raw, hi_raw = mm[0], mm[1]
    # Bin over the folded range but return the raw extrema for collectives.
    lo = jnp.minimum(lo_raw, hi_raw)
    hist = histogram_kernel(loss, valid, lo, hi_raw, bins=bins, blk_n=blk_n,
                            interpret=interpret)
    return hist, lo_raw, hi_raw
