"""Loss-histogram Pallas kernels for O(N) hidden-sample selection.

The paper's selection sorts all N lagging losses (O(N log N), its own listed
bottleneck in Table 1).  The optimized selection replaces the sort with a
fixed 512-bin histogram + CDF threshold (core/selection.py, method
``"histogram_pallas"``).  Two streaming passes over the losses:

1. ``minmax_kernel`` — the range pass: per-tile masked min/max reduced into
   a persistent 2-scalar SMEM accumulator, yielding the raw (lo, hi) bin
   range of the valid losses.
2. ``histogram_kernel`` — loss tiles land in VMEM, are binned via a one-hot
   iota compare (VPU) and reduced into a persistent (bins,) scratch
   accumulator across the sequential grid.

Both return *raw* local reductions so a sharded caller can psum/pmin/pmax
them before deriving the CDF threshold (see select_hidden_histogram).

The module also hosts the *exact* count-then-select path (radix select):
the rank-based plans — FORGET's ``topk_hide`` and DropTop's top-tail mask —
used to pay a full ``argsort`` (the O(N log N) bottleneck the paper lists in
Table 1) just to threshold at the k-th order statistic.  ``rank_select_mask``
finds the exact k-th smallest sort key with four streaming 256-bin byte
histograms (MSB-first radix passes over a monotonic f32->uint32 key map),
then emits the mask in one more streaming pass with a running tie counter —
five O(N) passes total, bit-identical to the stable-argsort mask including
index tie-breaks.  ``byte_histogram_kernel`` / ``select_mask_kernel`` are
the Pallas twins of the jnp passes; both paths share the driver, so parity
is structural.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend

# Sentinel for masked min/max: finite so f32 arithmetic on it stays exact
# and (lo - hi) on an all-invalid input does not produce inf/nan.
BIG = 3.4e38


def _kernel(loss_ref, valid_ref, range_ref, hist_ref, acc_ref, *, bins: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lo, hi = range_ref[0], range_ref[1]
    span = jnp.maximum(hi - lo, 1e-12)
    x = loss_ref[...].astype(jnp.float32)            # (blk_n,)
    valid = valid_ref[...] != 0                      # (blk_n,)
    idx = jnp.clip(((x - lo) / span * bins).astype(jnp.int32), 0, bins - 1)
    # one-hot accumulate: (blk_n, bins) compare + column sum (VPU-friendly;
    # no scatter needed, which TPU vector memory dislikes)
    onehot = (idx[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (x.shape[0], bins), 1))
    onehot = jnp.where(valid[:, None], onehot, False)
    acc_ref[...] += jnp.sum(onehot.astype(jnp.int32), axis=0)

    @pl.when(i == pl.num_programs(0) - 1)
    def _final():
        hist_ref[...] = acc_ref[...]


def histogram_kernel(loss: jax.Array, valid: jax.Array, lo: jax.Array,
                     hi: jax.Array, bins: int = 512, blk_n: int = 2048,
                     interpret: bool | None = None) -> jax.Array:
    """loss: (N,) f32; valid: (N,) bool/int. Returns (bins,) i32 histogram."""
    interpret = backend.resolve(interpret)
    n = loss.shape[0]
    blk_n = min(blk_n, n)
    assert n % blk_n == 0, (n, blk_n)
    rng = jnp.stack([jnp.asarray(lo, jnp.float32), jnp.asarray(hi, jnp.float32)])
    return pl.pallas_call(
        functools.partial(_kernel, bins=bins),
        grid=(n // blk_n,),
        in_specs=[
            pl.BlockSpec((blk_n,), lambda i: (i,)),
            pl.BlockSpec((blk_n,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((bins,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bins,), jnp.int32)],
        interpret=interpret,
    )(loss, valid.astype(jnp.int32), rng)


def _minmax_kernel(loss_ref, valid_ref, out_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[0] = jnp.float32(BIG)
        acc_ref[1] = jnp.float32(-BIG)

    x = loss_ref[...].astype(jnp.float32)            # (blk_n,)
    valid = valid_ref[...] != 0                      # (blk_n,)
    acc_ref[0] = jnp.minimum(acc_ref[0], jnp.min(jnp.where(valid, x, BIG)))
    acc_ref[1] = jnp.maximum(acc_ref[1], jnp.max(jnp.where(valid, x, -BIG)))

    @pl.when(i == pl.num_programs(0) - 1)
    def _final():
        out_ref[0] = acc_ref[0]
        out_ref[1] = acc_ref[1]


def minmax_kernel(loss: jax.Array, valid: jax.Array, blk_n: int = 2048,
                  interpret: bool | None = None) -> jax.Array:
    """Range pass: (N,) loss + valid mask -> (2,) f32 raw [lo, hi].

    Raw means no degeneracy fold: an all-invalid input yields
    [BIG, -BIG], which the caller collapses (lo = min(lo, hi)) *after* any
    cross-shard pmin/pmax so sharded and single-device results agree.
    """
    interpret = backend.resolve(interpret)
    n = loss.shape[0]
    blk_n = min(blk_n, n)
    assert n % blk_n == 0, (n, blk_n)
    return pl.pallas_call(
        _minmax_kernel,
        grid=(n // blk_n,),
        in_specs=[
            pl.BlockSpec((blk_n,), lambda i: (i,)),
            pl.BlockSpec((blk_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.float32),
        scratch_shapes=[pltpu.SMEM((2,), jnp.float32)],
        interpret=interpret,
    )(loss, valid.astype(jnp.int32))


def histogram_with_range(loss: jax.Array, valid: jax.Array, bins: int = 512,
                         blk_n: int = 2048, interpret: bool | None = None
                         ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused two-pass selection front end: (hist, lo_raw, hi_raw).

    The range pass feeds the histogram pass on device; nothing crosses the
    host boundary.
    """
    mm = minmax_kernel(loss, valid, blk_n=blk_n, interpret=interpret)
    lo_raw, hi_raw = mm[0], mm[1]
    # Bin over the folded range but return the raw extrema for collectives.
    lo = jnp.minimum(lo_raw, hi_raw)
    hist = histogram_kernel(loss, valid, lo, hi_raw, bins=bins, blk_n=blk_n,
                            interpret=interpret)
    return hist, lo_raw, hi_raw


# ---------------------------------------------------------------------------
# Exact count-then-select (radix select): the argsort replacement for the
# rank-based plans (FORGET topk_hide, DropTop's top tail)
# ---------------------------------------------------------------------------

#: Radix passes walk the uint32 sort key one byte at a time, MSB first.
RADIX_SHIFTS = (24, 16, 8, 0)
#: Padding key for the kernel path: the largest uint32, so padded slots rank
#: strictly after every real (non-NaN) key and can never claim a slot.
PAD_KEY = 0xFFFFFFFF


def float_order_keys(scores: jax.Array) -> jax.Array:
    """Monotonic f32 -> uint32 key map: a < b  <=>  key(a) < key(b).

    The standard sign-flip trick (negative floats get their bits inverted,
    positives get the sign bit set), with ``-0.0`` collapsed onto ``+0.0``
    first — a stable argsort treats signed zeros as ties and so must the
    radix path.  The collapse is a select on ``x == 0``, NOT ``x + 0.0``:
    XLA folds the add away under jit and ``-0.0`` would leak a smaller key.
    +/-inf order correctly; NaNs map above +inf (like jnp.argsort's
    NaNs-last) but carry payload bits, so callers that may see NaNs mask
    them first (as ``sort_high_mask`` does).
    """
    x = scores.astype(jnp.float32)
    b = jax.lax.bitcast_convert_type(x, jnp.uint32)
    b = jnp.where(x == 0, jnp.uint32(0), b)       # canonicalize -0.0
    sign = (b & jnp.uint32(0x80000000)) != 0
    return jnp.where(sign, ~b, b | jnp.uint32(0x80000000))


def _prefix_mask(shift: int) -> jnp.ndarray:
    """uint32 mask of the key bits already fixed by earlier radix passes."""
    return jnp.uint32((0xFFFFFFFF << (shift + 8)) & 0xFFFFFFFF
                      if shift < 24 else 0)


def _byte_histogram_jnp(keys: jax.Array, prefix: jax.Array,
                        shift: int) -> jax.Array:
    """(256,) counts of byte ``shift`` among keys matching ``prefix``."""
    match = (keys & _prefix_mask(shift)) == prefix
    bucket = ((keys >> shift) & jnp.uint32(0xFF)).astype(jnp.int32)
    return jnp.zeros((256,), jnp.int32).at[bucket].add(
        match.astype(jnp.int32))


def _byte_histogram_kernel(keys_ref, prefix_ref, hist_ref, acc_ref, *,
                           shift: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k = keys_ref[...]                                # (blk_n,) uint32
    match = (k & _prefix_mask(shift)) == prefix_ref[0]
    bucket = ((k >> shift) & jnp.uint32(0xFF)).astype(jnp.int32)
    # one-hot accumulate, same VPU-friendly pattern as histogram_kernel
    onehot = (bucket[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (k.shape[0], 256), 1))
    onehot = jnp.where(match[:, None], onehot, False)
    acc_ref[...] += jnp.sum(onehot.astype(jnp.int32), axis=0)

    @pl.when(i == pl.num_programs(0) - 1)
    def _final():
        hist_ref[...] = acc_ref[...]


def byte_histogram_kernel(keys: jax.Array, prefix: jax.Array, shift: int,
                          blk_n: int = 2048,
                          interpret: bool | None = None) -> jax.Array:
    """Streaming twin of ``_byte_histogram_jnp``; keys (N,) uint32,
    N % blk_n == 0 (the driver pads with PAD_KEY)."""
    interpret = backend.resolve(interpret)
    n = keys.shape[0]
    blk_n = min(blk_n, n)
    assert n % blk_n == 0, (n, blk_n)
    return pl.pallas_call(
        functools.partial(_byte_histogram_kernel, shift=shift),
        grid=(n // blk_n,),
        in_specs=[
            pl.BlockSpec((blk_n,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((256,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((256,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((256,), jnp.int32)],
        interpret=interpret,
    )(keys, prefix.reshape(1))


def _select_mask_jnp(keys, thresh, tie_lo, tie_hi):
    """mask = key < T, plus the (tie_lo, tie_hi] window of ties in index
    order — the exact stable-argsort tie-break."""
    tie = keys == thresh
    cum = jnp.cumsum(tie.astype(jnp.int32))
    return (keys < thresh) | (tie & (cum > tie_lo) & (cum <= tie_hi))


def _select_mask_kernel(keys_ref, thresh_ref, win_ref, mask_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = jnp.int32(0)

    k = keys_ref[...]
    t = thresh_ref[0]
    tie = k == t
    cum = carry_ref[0] + jnp.cumsum(tie.astype(jnp.int32))
    mask_ref[...] = ((k < t)
                     | (tie & (cum > win_ref[0]) & (cum <= win_ref[1])
                        )).astype(jnp.int32)
    carry_ref[0] = carry_ref[0] + jnp.sum(tie.astype(jnp.int32))


def select_mask_kernel(keys: jax.Array, thresh: jax.Array, tie_lo: jax.Array,
                       tie_hi: jax.Array, blk_n: int = 2048,
                       interpret: bool | None = None) -> jax.Array:
    """Streaming twin of ``_select_mask_jnp``: one pass, a 1-scalar SMEM
    running tie count carried across blocks.  Returns (N,) i32 0/1."""
    interpret = backend.resolve(interpret)
    n = keys.shape[0]
    blk_n = min(blk_n, n)
    assert n % blk_n == 0, (n, blk_n)
    win = jnp.stack([jnp.asarray(tie_lo, jnp.int32),
                     jnp.asarray(tie_hi, jnp.int32)])
    return pl.pallas_call(
        _select_mask_kernel,
        grid=(n // blk_n,),
        in_specs=[
            pl.BlockSpec((blk_n,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((blk_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(keys, thresh.reshape(1), win)


def radix_threshold(keys: jax.Array, k: jax.Array, hist_fn):
    """Exact k-th smallest key via 4 MSB-first byte-histogram passes.

    Returns ``(thresh, needed, total_ties)``: the k-th order statistic
    ``thresh`` (for k <= 0 the all-zero key: nothing selected), how many of
    the ties *at* ``thresh`` the mask still needs (``needed``), and the
    total tie count at ``thresh``.  ``hist_fn(keys, prefix, shift)`` is the
    jnp or Pallas byte-histogram pass — the only part the backends swap.
    """
    prefix = jnp.uint32(0)
    remaining = jnp.asarray(k, jnp.int32)
    hist = None
    b = jnp.int32(0)
    for shift in RADIX_SHIFTS:
        hist = hist_fn(keys, prefix, shift)
        cdf = jnp.cumsum(hist)
        # bucket holding the remaining-th smallest key of the prefix subset
        b = jnp.clip(jnp.searchsorted(cdf, remaining, side="left"), 0, 255)
        remaining = remaining - jnp.where(b > 0, cdf[jnp.maximum(b - 1, 0)], 0)
        prefix = prefix | (b.astype(jnp.uint32) << shift)
    # last pass's bucket = exact-key matches: the tie population at thresh
    return prefix, remaining, hist[b]


def rank_select_mask(scores: jax.Array, k: jax.Array, high: bool = False,
                     use_kernel: bool = False, blk_n: int = 2048,
                     interpret: bool | None = None) -> jax.Array:
    """Exact mask of the ``k`` smallest (or ``high=True``: largest) scores.

    Bit-identical to the stable-argsort masks it replaces (non-NaN inputs):

    - ``high=False``: ``stable_rank_order(scores) < k`` — ties at the
      threshold value break toward *smaller* indices (stable ascending
      sort), so the tie window takes the first ``needed`` ties;
    - ``high=True``: ranks ``>= n - k`` of a stable ascending argsort —
      there the threshold ties with the *largest* indices occupy the top
      window, so the tie window takes the last ``needed`` ties (computed
      from the same forward streaming pass via the total tie count).

    Cost: 5 streaming O(N) passes (4 byte histograms + the mask pass), no
    O(N log N) sort and no O(N)-sized gather/scatter of ranks.  ``k`` may be
    a traced scalar.  ``use_kernel`` swaps the jnp passes for the Pallas
    streaming kernels (same driver, structurally identical math).
    """
    keys = float_order_keys(scores)
    if high:
        keys = ~keys           # k largest = k smallest complemented keys
    if use_kernel:
        n = keys.shape[0]
        blk = min(blk_n, n)
        if n % blk:
            keys = jnp.pad(keys, (0, blk - n % blk),
                           constant_values=np.uint32(PAD_KEY))

        def hist_fn(ks, prefix, shift):
            return byte_histogram_kernel(ks, prefix, shift, blk_n=blk,
                                         interpret=interpret)
    else:
        n = keys.shape[0]

        def hist_fn(ks, prefix, shift):
            return _byte_histogram_jnp(ks, prefix, shift)

    thresh, needed, total_ties = radix_threshold(keys, k, hist_fn)
    if high:
        tie_lo, tie_hi = total_ties - needed, total_ties
    else:
        tie_lo, tie_hi = jnp.int32(0), needed
    if use_kernel:
        mask = select_mask_kernel(keys, thresh, tie_lo, tie_hi, blk_n=blk,
                                  interpret=interpret)[:n]
        return mask != 0
    return _select_mask_jnp(keys, thresh, tie_lo, tie_hi)
