"""Flash attention Pallas kernel (TPU target, validated in interpret mode).

Grid (B*Hq, S/blk_q, S/blk_k); the K dimension is the innermost (sequential)
axis so the online-softmax accumulators live in VMEM scratch across K steps.
GQA is handled in the K/V index maps (``bh // group``) — K/V are never
repeated in HBM.  Block sizes default to 128 (MXU-aligned).

VMEM working set per step: q(blk_q x D) + k,v(blk_k x D) + acc(blk_q x D f32)
+ scores(blk_q x blk_k f32) ~ 0.5 MB at D=128 — comfortably inside the
16 MB/core VMEM budget with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, blk_q: int, blk_k: int):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)          # (blk_q, D)
    k = k_ref[0].astype(jnp.float32)          # (blk_k, D)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    if causal:
        qpos = iq * blk_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = ik * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _final():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, blk_q: int = 128, blk_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B,S,Hq,D); k,v: (B,S,Hkv,D). Returns (B,S,Hq,D)."""
    interpret = backend.resolve(interpret)
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    blk_q = min(blk_q, s)
    blk_k = min(blk_k, s)
    assert s % blk_q == 0 and s % blk_k == 0, (s, blk_q, blk_k)

    # (B*H, S, D) layout: contiguous per (batch, head) row for clean tiling.
    qr = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)

    grid = (b * hq, s // blk_q, s // blk_k)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=d ** -0.5, causal=causal,
                          blk_q=blk_q, blk_k=blk_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            # GQA: q head bh maps to kv head (bh %% hq) // g of batch bh // hq
            pl.BlockSpec((1, blk_k, d),
                         lambda bh, iq, ik: ((bh // hq) * hkv + (bh % hq) // g,
                                             ik, 0)),
            pl.BlockSpec((1, blk_k, d),
                         lambda bh, iq, ik: ((bh // hq) * hkv + (bh % hq) // g,
                                             ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, d), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
