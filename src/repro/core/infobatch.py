"""InfoBatch baseline [28] (paper App. E / C.4 discussion; Qin et al. 2023).

Lossless dynamic pruning: each epoch, randomly prune a fraction ``r`` of the
samples whose (lagging) loss is below the running mean, and RESCALE the loss
of the kept below-mean samples by 1/(1-r) so the expected gradient is
unbiased — the property KAKURENBO approximates globally with its Eq. 8 LR
adjustment.  No pruning during the final ``anneal`` fraction of training
(the paper's InfoBatch recipe).

Included because the paper positions itself against it (App. C.4): having
both in one framework lets the comparison run under identical substrates.

Planning is device-resident (``core/planops.py``): the below-mean soft prune
(``planops.weighted_keep``) and the visible-first epoch shuffle
(``planops.masked_order``) are one jitted plan step on the device
``SampleState``, driven by a checkpointable PRNG key; the epoch order, prune
count and rescale weights cross to the host in a single ``jax.device_get``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import planops
from repro.core.state import SampleState, init_sample_state, scatter_observations
from repro.core.strategy import EpochPlan, SampleStrategy, register_strategy
from repro.dist.sharding import ParallelCtx


@dataclasses.dataclass
class InfoBatchConfig:
    prune_ratio: float = 0.5   # r: fraction of below-mean samples pruned
    anneal: float = 0.875      # stop pruning after this fraction of epochs
    total_epochs: int = 100


@functools.partial(jax.jit, static_argnames=("annealed", "mesh"))
def _plan_step(state: SampleState, key: jax.Array, prune_ratio: float, *,
               annealed: bool, mesh=None):
    """Device epoch plan: soft prune + rescale weights + epoch shuffle.

    Returns (order with kept samples first, prune count, weights).  During
    the anneal phase (static per-epoch flag) the prune mask is empty and the
    weights uniform; with nothing observed yet ``weighted_keep`` yields the
    same (no below-mean set), so cold-start epochs train on everything.
    """
    n = state.num_samples
    k_prune, k_shuffle = jax.random.split(key)
    if annealed:
        prune = jnp.zeros((n,), bool)
        weights = jnp.ones((n,), jnp.float32)
    else:
        prune, weights = planops.weighted_keep(
            k_prune, state.loss, state.seen >= 0, prune_ratio, mesh=mesh)
    order, num_prune = planops.masked_order(k_shuffle, prune, mesh=mesh)
    return order, num_prune, weights


class InfoBatchSampler:
    def __init__(self, num_samples: int, config: InfoBatchConfig | None = None,
                 seed: int = 0, ctx: ParallelCtx | None = None):
        self.config = config or InfoBatchConfig()
        self.ctx = ctx or ParallelCtx()
        self.ctx.check_rows(num_samples)
        self.state: SampleState = self.ctx.shard_rows(
            init_sample_state(num_samples, init_loss=1e9))
        self._key = self.ctx.replicate(planops.strategy_key(seed, "infobatch"))
        self._observe = jax.jit(scatter_observations)
        self.weights = np.ones(num_samples, np.float32)

    def begin_epoch(self, epoch: int) -> tuple[np.ndarray, np.ndarray]:
        """Returns (shuffled kept indices, sorted pruned indices)."""
        c = self.config
        n = self.state.num_samples
        annealed = epoch >= int(c.anneal * c.total_epochs)
        self._key, sub = jax.random.split(self._key)
        order, num_prune, weights = _plan_step(
            self.state, sub, c.prune_ratio, annealed=annealed,
            mesh=self.ctx.mesh)
        # The single host sync of the epoch: order + count + weights.
        order, num_prune, weights = jax.device_get(
            (order, num_prune, weights))
        self.weights = np.asarray(weights)
        num_prune = int(num_prune)
        order = np.asarray(order)
        return order[: n - num_prune], np.sort(order[n - num_prune:])

    def sample_weights(self, indices: np.ndarray) -> np.ndarray:
        return self.weights[indices]

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        self.state = self._observe(self.state, jnp.asarray(indices), loss, pa,
                                   pc, epoch)

    def batches(self, epoch_indices: np.ndarray, batch_size: int) -> Iterator[np.ndarray]:
        for start in range(0, len(epoch_indices) - batch_size + 1, batch_size):
            yield epoch_indices[start : start + batch_size]


@register_strategy("infobatch")
class InfoBatchStrategy(SampleStrategy):
    """Lossless dynamic pruning with 1/(1-r) rescaling weights."""

    config_cls, config_field = InfoBatchConfig, "infobatch"
    fused_observe = staticmethod(scatter_observations)

    def __init__(self, num_samples: int, config: InfoBatchConfig | None = None,
                 seed: int = 0, total_epochs: int | None = None,
                 ctx: ParallelCtx | None = None):
        cfg = config or InfoBatchConfig()
        if total_epochs is not None:
            cfg = dataclasses.replace(cfg, total_epochs=total_epochs)
        super().__init__(num_samples, cfg, seed)
        self._inner = InfoBatchSampler(num_samples, cfg, seed, ctx=ctx)

    @property
    def state(self) -> SampleState:
        return self._inner.state

    def get_device_state(self) -> SampleState:
        return self._inner.state

    def set_device_state(self, state: SampleState) -> None:
        self._inner.state = state

    def plan(self, epoch: int) -> EpochPlan:
        # begin_epoch materialises the plan with one device_get: 1 host sync.
        visible, pruned = self._inner.begin_epoch(epoch)
        return EpochPlan(epoch=epoch, visible_indices=visible,
                         hidden_indices=pruned,
                         hidden_fraction=len(pruned) / self.num_samples,
                         host_syncs=1)

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        self._inner.observe(indices, loss, pa, pc, epoch)

    def batch_weights(self, indices: np.ndarray) -> np.ndarray:
        return self._inner.sample_weights(indices)

    def state_dict(self) -> dict:
        # weights are not saved: begin_epoch() rebuilds them from the state
        # before any weight lookup after a restore.
        return {"arrays": {"state": self._inner.state,
                           "rng_key": planops.key_data(self._inner._key)},
                "host": {"rng_impl": planops.KEY_IMPL}}

    def load_state_dict(self, state: dict) -> None:
        self._inner.state = self._inner.ctx.shard_rows(
            jax.tree.map(jnp.asarray, state["arrays"]["state"]))
        # restore_key also migrates pre-PlanOps checkpoints (host numpy RNG).
        self._inner._key = self._inner.ctx.replicate(
            planops.restore_key(state, self.seed, "infobatch"))
