"""InfoBatch baseline [28] (paper App. E / C.4 discussion; Qin et al. 2023).

Lossless dynamic pruning: each epoch, randomly prune a fraction ``r`` of the
samples whose (lagging) loss is below the running mean, and RESCALE the loss
of the kept below-mean samples by 1/(1-r) so the expected gradient is
unbiased — the property KAKURENBO approximates globally with its Eq. 8 LR
adjustment.  No pruning during the final ``anneal`` fraction of training
(the paper's InfoBatch recipe).

Included because the paper positions itself against it (App. C.4): having
both in one framework lets the comparison run under identical substrates.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state import SampleState, init_sample_state, scatter_observations
from repro.core.strategy import (
    EpochPlan, SampleStrategy, register_strategy, rng_state, set_rng_state,
)


@dataclasses.dataclass
class InfoBatchConfig:
    prune_ratio: float = 0.5   # r: fraction of below-mean samples pruned
    anneal: float = 0.875      # stop pruning after this fraction of epochs
    total_epochs: int = 100


class InfoBatchSampler:
    def __init__(self, num_samples: int, config: InfoBatchConfig | None = None,
                 seed: int = 0):
        self.config = config or InfoBatchConfig()
        self.state: SampleState = init_sample_state(num_samples, init_loss=1e9)
        self._rng = np.random.default_rng(seed)
        self._observe = jax.jit(scatter_observations)
        self.weights = np.ones(num_samples, np.float32)

    def begin_epoch(self, epoch: int) -> np.ndarray:
        c = self.config
        n = self.state.num_samples
        self.weights = np.ones(n, np.float32)
        seen = np.asarray(self.state.seen) >= 0
        annealed = epoch >= int(c.anneal * c.total_epochs)
        if not seen.any() or annealed:
            idx = np.arange(n)
        else:
            loss = np.asarray(self.state.loss)
            mean = loss[seen].mean()
            below = seen & (loss < mean)
            prune = below & (self._rng.random(n) < c.prune_ratio)
            # kept below-mean samples are up-weighted: unbiased expectation
            self.weights[below & ~prune] = 1.0 / (1.0 - c.prune_ratio)
            idx = np.arange(n)[~prune]
        self._rng.shuffle(idx)
        return idx

    def sample_weights(self, indices: np.ndarray) -> np.ndarray:
        return self.weights[indices]

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        self.state = self._observe(self.state, jnp.asarray(indices), loss, pa,
                                   pc, epoch)

    def batches(self, epoch_indices: np.ndarray, batch_size: int) -> Iterator[np.ndarray]:
        for start in range(0, len(epoch_indices) - batch_size + 1, batch_size):
            yield epoch_indices[start : start + batch_size]


@register_strategy("infobatch")
class InfoBatchStrategy(SampleStrategy):
    """Lossless dynamic pruning with 1/(1-r) rescaling weights."""

    config_cls, config_field = InfoBatchConfig, "infobatch"
    fused_observe = staticmethod(scatter_observations)

    def __init__(self, num_samples: int, config: InfoBatchConfig | None = None,
                 seed: int = 0, total_epochs: int | None = None):
        cfg = config or InfoBatchConfig()
        if total_epochs is not None:
            cfg = dataclasses.replace(cfg, total_epochs=total_epochs)
        super().__init__(num_samples, cfg, seed)
        self._inner = InfoBatchSampler(num_samples, cfg, seed)

    @property
    def state(self) -> SampleState:
        return self._inner.state

    def get_device_state(self) -> SampleState:
        return self._inner.state

    def set_device_state(self, state: SampleState) -> None:
        self._inner.state = state

    def plan(self, epoch: int) -> EpochPlan:
        # begin_epoch materialises loss/seen for the pruning: 1 host sync.
        return EpochPlan(epoch=epoch,
                         visible_indices=self._inner.begin_epoch(epoch),
                         host_syncs=1)

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        self._inner.observe(indices, loss, pa, pc, epoch)

    def batch_weights(self, indices: np.ndarray) -> np.ndarray:
        return self._inner.sample_weights(indices)

    def state_dict(self) -> dict:
        # weights are not saved: begin_epoch() rebuilds them from the state
        # before any weight lookup after a restore.
        return {"arrays": {"state": self._inner.state},
                "host": {"rng": rng_state(self._inner._rng)}}

    def load_state_dict(self, state: dict) -> None:
        self._inner.state = jax.tree.map(jnp.asarray, state["arrays"]["state"])
        set_rng_state(self._inner._rng, state["host"]["rng"])
