"""Trainer-special-case-free baseline strategies.

``baseline`` — uniform shuffle over the full dataset, the control every
paper table is measured against.  ``random`` — KAKURENBO's machinery driven
by iid-uniform importance (paper App. C.4): hides the same *fraction* as
KAKURENBO but picks the samples at random, isolating how much of the win
comes from loss-ranked selection rather than from merely training on fewer
samples.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kakurenbo import KakurenboConfig, KakurenboSampler
from repro.core.state import scatter_observations
from repro.core.strategy import (
    EpochPlan, SampleStrategy, register_strategy, rng_state, set_rng_state,
)


@register_strategy("baseline")
class BaselineStrategy(SampleStrategy):
    """Uniform without-replacement epoch over every sample."""

    def __init__(self, num_samples: int, config=None, seed: int = 0):
        super().__init__(num_samples, config, seed)
        self._rng = np.random.default_rng(seed + 1)

    def plan(self, epoch: int) -> EpochPlan:
        idx = np.arange(self.num_samples)
        self._rng.shuffle(idx)
        return EpochPlan(epoch=epoch, visible_indices=idx)

    def state_dict(self) -> dict:
        return {"arrays": {}, "host": {"rng": rng_state(self._rng)}}

    def load_state_dict(self, state: dict) -> None:
        set_rng_state(self._rng, state["host"]["rng"])


@register_strategy("random")
class RandomStrategy(SampleStrategy):
    """Random hiding (App. C.4): KAKURENBO with iid-uniform importance."""

    config_cls, config_field = KakurenboConfig, "kakurenbo"
    fused_observe = staticmethod(scatter_observations)

    def __init__(self, num_samples: int, config: KakurenboConfig | None = None,
                 seed: int = 0, ctx=None):
        super().__init__(num_samples, config, seed)
        self._inner = KakurenboSampler(
            num_samples, dataclasses.replace(config) if config else None, seed,
            ctx=ctx)
        self._rng = np.random.default_rng(seed + 1)

    @property
    def state(self):
        return self._inner.state

    def get_device_state(self):
        return self._inner.state

    def set_device_state(self, state) -> None:
        self._inner.state = state

    def _randomize_importance(self) -> None:
        """Overwrite the lagging state with iid-uniform 'losses' that are
        always move-back-eligible, so hiding is a pure coin flip."""
        n = self.num_samples
        self._inner.state = self._inner.ctx.shard_rows(dataclasses.replace(
            self._inner.state,
            loss=jnp.asarray(self._rng.random(n), jnp.float32),
            pa=jnp.ones((n,), bool),
            pc=jnp.ones((n,), jnp.float32),
            seen=jnp.zeros((n,), jnp.int32)))

    def plan(self, epoch: int) -> EpochPlan:
        self._randomize_importance()
        return self._inner.begin_epoch(epoch)

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        self._inner.observe(indices, loss, pa, pc, epoch)

    def on_epoch_end(self, plan: EpochPlan, eval_forward, batch_size: int) -> int:
        # Same refresh cost as KAKURENBO so the work accounting is an
        # apples-to-apples comparison (App. C.4).
        return self._inner.refresh_hidden(plan, eval_forward, batch_size)

    def state_dict(self) -> dict:
        return {"arrays": {"state": self._inner.state,
                           "inner_key": self._inner.key_data()},
                "host": {"rng": rng_state(self._rng)}}

    def load_state_dict(self, state: dict) -> None:
        self._inner.state = self._inner.ctx.shard_rows(
            jax.tree.map(jnp.asarray, state["arrays"]["state"]))
        self._inner.load_key_data(state["arrays"]["inner_key"])
        set_rng_state(self._rng, state["host"]["rng"])
