"""Trainer-special-case-free baseline strategies.

``baseline`` — uniform shuffle over the full dataset, the control every
paper table is measured against.  ``random`` — KAKURENBO's machinery driven
by iid-uniform importance (paper App. C.4): hides the same *fraction* as
KAKURENBO but picks the samples at random, isolating how much of the win
comes from loss-ranked selection rather than from merely training on fewer
samples.

Both plan on device through ``core/planops.py``: the epoch shuffle (and the
``random`` strategy's importance redraw) is driven by a checkpointable
device PRNG key and materialised to the ``EpochPlan`` with one
``jax.device_get`` — the same 1-host-sync/epoch contract as KAKURENBO.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import planops
from repro.core.kakurenbo import KakurenboConfig, KakurenboSampler
from repro.core.state import scatter_observations
from repro.core.strategy import EpochPlan, SampleStrategy, register_strategy
from repro.dist.sharding import ParallelCtx


@register_strategy("baseline")
class BaselineStrategy(SampleStrategy):
    """Uniform without-replacement epoch over every sample."""

    def __init__(self, num_samples: int, config=None, seed: int = 0,
                 ctx: ParallelCtx | None = None):
        super().__init__(num_samples, config, seed)
        self.ctx = ctx or ParallelCtx()
        self._key = self.ctx.replicate(planops.strategy_key(seed, "baseline"))

    def plan(self, epoch: int) -> EpochPlan:
        self._key, sub = jax.random.split(self._key)
        order = planops.device_permutation(sub, self.num_samples)
        # The epoch's single host sync: materialise the shuffled order.
        return EpochPlan(epoch=epoch,
                         visible_indices=np.asarray(jax.device_get(order)),
                         host_syncs=1)

    def state_dict(self) -> dict:
        return {"arrays": {"rng_key": planops.key_data(self._key)},
                "host": {"rng_impl": planops.KEY_IMPL}}

    def load_state_dict(self, state: dict) -> None:
        # restore_key also migrates pre-PlanOps checkpoints (host numpy RNG).
        self._key = self.ctx.replicate(
            planops.restore_key(state, self.seed, "baseline"))


@jax.jit
def _randomize_importance(state, key):
    """iid-uniform 'losses', always move-back-eligible: a pure coin flip."""
    n = state.num_samples
    return dataclasses.replace(
        state,
        loss=jax.random.uniform(key, (n,), jnp.float32),
        pa=jnp.ones((n,), bool),
        pc=jnp.ones((n,), jnp.float32),
        seen=jnp.zeros((n,), jnp.int32))


@register_strategy("random")
class RandomStrategy(SampleStrategy):
    """Random hiding (App. C.4): KAKURENBO with iid-uniform importance."""

    config_cls, config_field = KakurenboConfig, "kakurenbo"
    fused_observe = staticmethod(scatter_observations)

    def __init__(self, num_samples: int, config: KakurenboConfig | None = None,
                 seed: int = 0, ctx=None):
        super().__init__(num_samples, config, seed)
        self._inner = KakurenboSampler(
            num_samples, dataclasses.replace(config) if config else None, seed,
            ctx=ctx)
        self._key = self._inner.ctx.replicate(
            planops.strategy_key(seed, "random"))

    @property
    def state(self):
        return self._inner.state

    def get_device_state(self):
        return self._inner.state

    def set_device_state(self, state) -> None:
        self._inner.state = state

    def plan(self, epoch: int) -> EpochPlan:
        # Overwrite the lagging state with device-drawn iid importance, then
        # run the standard KAKURENBO plan step on it.
        self._key, sub = jax.random.split(self._key)
        self._inner.state = self._inner.ctx.shard_rows(
            _randomize_importance(self._inner.state, sub))
        return self._inner.begin_epoch(epoch)

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        self._inner.observe(indices, loss, pa, pc, epoch)

    def on_epoch_end(self, plan: EpochPlan, eval_forward, batch_size: int) -> int:
        # Same refresh cost as KAKURENBO so the work accounting is an
        # apples-to-apples comparison (App. C.4).
        return self._inner.refresh_hidden(plan, eval_forward, batch_size)

    def state_dict(self) -> dict:
        return {"arrays": {"state": self._inner.state,
                           "inner_key": self._inner.key_data(),
                           "rng_key": planops.key_data(self._key)},
                "host": {"rng_impl": planops.KEY_IMPL}}

    def load_state_dict(self, state: dict) -> None:
        self._inner.state = self._inner.ctx.shard_rows(
            jax.tree.map(jnp.asarray, state["arrays"]["state"]))
        self._inner.load_key_data(state["arrays"]["inner_key"])
        self._key = self._inner.ctx.replicate(
            planops.restore_key(state, self.seed, "random"))
