"""Schedules: maximum hidden fraction (Sec. 3.3) and LR adjustment (Sec. 3.2).

Also provides the baseline LR schedules the paper trains with (App. B.3):
step decay, cosine, constant — all with linear warmup and the linear-scaling
rule — so that KAKURENBO's Eq. 8 factor can wrap any of them.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Maximum hidden fraction schedule (paper Sec. 3.3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FractionSchedule:
    """F_e = F_max * alpha[i] for the largest milestone[i] <= e.

    Paper defaults: F_max=0.3, alpha=[1, 0.8, 0.6, 0.4] at epochs
    [0, 30, 60, 80] (ImageNet-1K) / [0, 60, 120, 180] (CIFAR-100).
    """

    max_fraction: float = 0.3
    alphas: Sequence[float] = (1.0, 0.8, 0.6, 0.4)
    milestones: Sequence[int] = (0, 30, 60, 80)

    def __post_init__(self):
        assert len(self.alphas) == len(self.milestones)
        assert 0.0 <= self.max_fraction < 1.0

    def __call__(self, epoch: jax.Array | int) -> jax.Array:
        e = jnp.asarray(epoch, jnp.int32)
        alpha = jnp.asarray(0.0, jnp.float32)
        for a, m in zip(self.alphas, self.milestones):
            alpha = jnp.where(e >= m, jnp.float32(a), alpha)
        return jnp.float32(self.max_fraction) * alpha


# ---------------------------------------------------------------------------
# Learning-rate schedules (paper App. B.3) + KAKURENBO Eq. 8 adjustment
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LRSchedule:
    """Base LR schedule eta_base(e) with linear warmup over warmup_epochs.

    kind: "step" (decay_rate at each milestone), "cosine" (anneal to 0 over
    total_epochs), or "constant".
    """

    base_lr: float
    kind: str = "cosine"
    total_epochs: int = 100
    warmup_epochs: int = 5
    decay_rate: float = 0.1
    milestones: Sequence[int] = (30, 60, 80)

    def __call__(self, epoch: jax.Array | int) -> jax.Array:
        e = jnp.asarray(epoch, jnp.float32)
        if self.kind == "step":
            lr = jnp.float32(self.base_lr)
            for m in self.milestones:
                lr = jnp.where(e >= m, lr * self.decay_rate, lr)
        elif self.kind == "cosine":
            frac = jnp.clip(
                (e - self.warmup_epochs)
                / max(self.total_epochs - self.warmup_epochs, 1),
                0.0,
                1.0,
            )
            lr = jnp.float32(self.base_lr) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        elif self.kind == "constant":
            lr = jnp.float32(self.base_lr)
        else:
            raise ValueError(f"unknown LR schedule {self.kind!r}")
        if self.warmup_epochs > 0:
            warm = jnp.clip((e + 1.0) / self.warmup_epochs, 0.0, 1.0)
            lr = jnp.where(e < self.warmup_epochs, jnp.float32(self.base_lr) * warm, lr)
        return lr


def kakurenbo_lr(base_lr: jax.Array, hidden_fraction: jax.Array) -> jax.Array:
    """Eq. 8: eta_e = eta_base,e / (1 - F_e).

    ``hidden_fraction`` is the *actual* hidden fraction F*_e this epoch (after
    move-back), which is what compensates the reduced number of SGD steps.
    Applied after warmup; independent of the underlying scheduler.
    """
    f = jnp.clip(jnp.asarray(hidden_fraction, jnp.float32), 0.0, 0.95)
    return base_lr / (1.0 - f)


def linear_scaling_rule(base_lr_per_worker: float, num_workers: int) -> float:
    """Goyal et al. [34] linear-scaling rule used by the paper's ResNet-50 (A)."""
    return base_lr_per_worker * num_workers
