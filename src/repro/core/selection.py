"""Hidden-sample selection.

Three interchangeable implementations of step B of the paper (Fig. 1),
selectable via ``KakurenboConfig.selection``:

1. ``"sort"`` — the *paper-faithful* method: rank every sample by lagging
   loss (O(N log N) sort, the complexity the paper itself reports in
   Table 1) and hide the lowest-loss fraction <= F, then apply the
   move-back rule (Sec. 3.1).

2. ``"histogram"`` — the *beyond-paper optimized* method: find the loss
   value t such that ~F*N samples have loss < t using a fixed-size
   histogram (one pass over the local shard + a bins-sized psum when run
   under shard_map), then hide {loss < t}.  O(N) compute, O(bins)
   communication — removes both the sort and the O(N)-sized all-gather.

3. ``"histogram_pallas"`` — the same histogram-CDF math with the range and
   histogram passes computed by the Pallas streaming kernels
   (kernels/threshold_select.py): loss tiles stay in VMEM, only (bins,) + 2
   scalars leave the kernel.  Bit-identical masks to ``"histogram"`` (same
   binning formula, exact integer counts), so the differential parity suite
   (tests/test_selection_parity.py) asserts equality, not tolerance.

All methods return a boolean hidden mask and honour the same move-back
rule: a candidate stays hidden only if it was *correctly predicted with
confidence >= tau* at its last observation; otherwise it is moved back to
the training list.  Never-seen samples (seen < 0) are never hidden.
DropTop (paper App. D) — additionally hiding the highest-loss tail — is
supported by every method; the histogram paths mirror the bottom-tail CDF
walk from the top bin down.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import planops
from repro.core.planops import HIST_BINS  # noqa: F401  (re-export)
from repro.core.state import SampleState

#: Methods accepted by ``select_hidden`` / ``KakurenboConfig.selection``.
SELECTION_METHODS = ("sort", "histogram", "histogram_pallas")


def _eligible(state: SampleState, tau: float, moveback: bool) -> jax.Array:
    """True where a sample is allowed to stay hidden.

    With move-back (paper Sec. 3.1) a candidate must have been confidently
    correct at its last observation; without it (Table 6 ablation) any
    observed sample may hide.  Never-seen samples are never hidden.
    """
    if not moveback:
        return state.seen >= 0
    return state.pa & (state.pc >= tau) & (state.seen >= 0)


def select_hidden_sort(
    state: SampleState,
    max_fraction: jax.Array | float,
    tau: float = 0.7,
    drop_top_fraction: float = 0.0,
    moveback: bool = True,
) -> jax.Array:
    """Paper-faithful selection: global sort by lagging loss.

    Args:
      state: SampleState with up-to-(an-epoch-stale) loss/PA/PC.
      max_fraction: F_e, the maximum hidden fraction for this epoch.
      tau: prediction-confidence threshold for move-back.
      drop_top_fraction: optional DropTop (paper App. D) — additionally hide
        this fraction of the *highest*-loss samples (noisy/unlearnable).
      moveback: apply the move-back rule (False = HE-only ablation).

    Returns:
      (N,) bool hidden mask. The actual hidden fraction F* <= F because of
      move-back.
    """
    # O(N log N) rank of each sample among the losses: the paper's own
    # complexity (planops.sort_low_mask is the shared implementation).
    candidate = planops.sort_low_mask(state.loss, max_fraction)
    hidden = candidate & _eligible(state, tau, moveback)
    if drop_top_fraction > 0.0:
        # DropTop ignores move-back: these are hard/noisy samples, hidden
        # unconditionally (App. D), but never-seen samples are exempt — and
        # must not *occupy* the top-rank window either (their sentinel
        # losses sort above every real loss), so planops.sort_high_mask
        # ranks them below everything; both histogram paths count only valid
        # samples, which keeps the three methods agreeing on the tail.
        top = planops.sort_high_mask(state.loss, state.seen >= 0,
                                     drop_top_fraction)
        hidden = hidden | top
    return hidden


def histogram_threshold(
    loss: jax.Array,
    valid: jax.Array,
    num_hide: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    bins: int = HIST_BINS,
) -> jax.Array:
    """Loss threshold t s.t. |{valid & loss < t}| ~ num_hide, via histogram CDF.

    Pure-jnp reference; the Pallas `threshold_select` kernel computes the same
    local histogram with VMEM tiling. Under shard_map the histogram is psum'd
    over the data axes before the CDF scan (see kakurenbo.py).
    """
    span = jnp.maximum(hi - lo, 1e-12)
    idx = jnp.clip(((loss - lo) / span * bins).astype(jnp.int32), 0, bins - 1)
    hist = jnp.zeros((bins,), jnp.int32).at[idx].add(valid.astype(jnp.int32))
    cdf = jnp.cumsum(hist)
    # Smallest bin b with cdf[b] >= num_hide; threshold = right edge of b.
    b = jnp.searchsorted(cdf, num_hide, side="left")
    b = jnp.clip(b, 0, bins - 1)
    return lo + (b.astype(jnp.float32) + 1.0) * span / bins


def select_hidden_histogram(
    state: SampleState,
    max_fraction: jax.Array | float,
    tau: float = 0.7,
    bins: int = HIST_BINS,
    axis_names: tuple[str, ...] = (),
    drop_top_fraction: float = 0.0,
    moveback: bool = True,
    use_kernel: bool = False,
) -> jax.Array:
    """Optimized selection: histogram-CDF threshold instead of a sort.

    With ``axis_names`` non-empty this runs inside shard_map over the data
    axes: local histograms are psum'd so every shard derives the same global
    threshold from O(bins) communicated scalars.

    ``use_kernel=True`` computes the range and histogram passes with the
    Pallas streaming kernels (method ``"histogram_pallas"``); the threshold
    and mask math is shared, so both paths produce bit-identical masks.

    Guarantees hidden_count <= floor(F*N) + (boundary-bin slack); the CDF
    walk cannot split the boundary bin without a rank tie-break, so it is
    either excluded (undershoot — always safe, F is a ceiling, Sec. 3.1) or
    included when excluding it would under-fill by more than half the bin.
    """
    # The histogram-CDF core (range pass, binning, psum, boundary-bin rule,
    # optional mirrored DropTop walk) is shared with the generic PlanOps
    # library — see planops.histogram_masks for the boundary-bin contract.
    candidate, top = planops.histogram_masks(
        state.loss, state.seen >= 0, max_fraction, drop_top_fraction,
        bins=bins, axis_names=axis_names, use_kernel=use_kernel)
    hidden = candidate & _eligible(state, tau, moveback)
    if top is not None:
        hidden = hidden | top
    return hidden


@functools.partial(
    jax.jit, static_argnames=("method", "tau", "drop_top_fraction", "moveback"))
def select_hidden(
    state: SampleState,
    max_fraction: jax.Array | float,
    *,
    method: str = "sort",
    tau: float = 0.7,
    drop_top_fraction: float = 0.0,
    moveback: bool = True,
) -> jax.Array:
    """Jitted single-device entry point (plan step, tests, examples).

    The mesh plan (``core/kakurenbo.py::_plan_step``) calls
    ``select_hidden_histogram`` directly under shard_map for the histogram
    methods (O(bins) psum) and falls back to this global path for
    ``"sort"`` (GSPMD argsort, O(N) gather).
    """
    if method == "sort":
        return select_hidden_sort(state, max_fraction, tau, drop_top_fraction,
                                  moveback)
    elif method in ("histogram", "histogram_pallas"):
        return select_hidden_histogram(
            state, max_fraction, tau,
            drop_top_fraction=drop_top_fraction, moveback=moveback,
            use_kernel=(method == "histogram_pallas"))
    raise ValueError(
        f"unknown selection method {method!r}; known: {SELECTION_METHODS}")
