"""Grad-Match baseline [18] (paper Sec. 4, single-GPU comparison only).

Every R epochs, select a per-class subset whose weighted last-layer gradient
sum matches the full-dataset last-layer gradient, via orthogonal matching
pursuit (OMP).  Following the paper's approximations: last-layer gradients
only, per-class decomposition, subset + weights frozen for the next R epochs.

The paper itself concludes Grad-Match is impractical for distributed training
(the per-class gather is a huge collective); we therefore implement it as a
single-host method for the classification configs — exactly the setting of
the paper's Table 3 — and do not wire it into the pjit path.  This is a
deliberate scope decision mirroring the paper (DESIGN.md Sec. 3).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import numpy as np

from repro.core import planops
from repro.core.strategy import (
    EpochPlan, FeatsFn, SampleStrategy, register_strategy,
)


@dataclasses.dataclass
class GradMatchConfig:
    fraction: float = 0.3     # keep 1-fraction of the data
    interval: int = 5          # R: re-select every R epochs
    lam: float = 0.5           # OMP ridge regularizer


def _omp_select(G: np.ndarray, budget: int, lam: float) -> tuple[np.ndarray, np.ndarray]:
    """Greedy OMP: pick ``budget`` rows of G whose weighted sum matches G.sum(0).

    G: (n, d) per-sample last-layer gradient features.
    Returns (indices, weights).
    """
    n = G.shape[0]
    budget = min(budget, n)
    target = G.sum(axis=0)
    residual = target.copy()
    chosen: list[int] = []
    mask = np.zeros(n, bool)
    for _ in range(budget):
        scores = G @ residual
        scores[mask] = -np.inf
        j = int(np.argmax(scores))
        if not np.isfinite(scores[j]):
            break
        chosen.append(j)
        mask[j] = True
        A = G[chosen]  # (k, d)
        # ridge least squares for weights: min ||A^T w - target||^2 + lam||w||^2
        k = len(chosen)
        w = np.linalg.solve(A @ A.T + lam * np.eye(k), A @ target)
        residual = target - A.T @ w
    return np.array(chosen, np.int64), np.maximum(np.array(w if chosen else []), 0.0)


class GradMatchSampler:
    def __init__(self, num_samples: int, num_classes: int,
                 config: GradMatchConfig | None = None, seed: int = 0):
        self.config = config or GradMatchConfig()
        self.n = num_samples
        self.num_classes = num_classes
        # Device epoch-shuffle key (planops convention); the OMP itself stays
        # host-side by design (see module docstring).
        self._key = planops.strategy_key(seed, "gradmatch")
        self.subset = np.arange(num_samples)
        self.weights = np.ones(num_samples, np.float32)

    def maybe_reselect(self, epoch: int, grad_feats: np.ndarray,
                       labels: np.ndarray) -> bool:
        """grad_feats: (N, d) last-layer grad proxies (e.g. p - onehot(y))."""
        if epoch % self.config.interval != 0:
            return False
        keep_frac = 1.0 - self.config.fraction
        idx_all, w_all = [], []
        for c in range(self.num_classes):
            cls = np.nonzero(labels == c)[0]
            if len(cls) == 0:
                continue
            budget = max(1, int(round(keep_frac * len(cls))))
            sel, w = _omp_select(grad_feats[cls], budget, self.config.lam)
            idx_all.append(cls[sel])
            w_all.append(w)
        self.subset = np.concatenate(idx_all)
        w = np.concatenate(w_all).astype(np.float32)
        # normalize so mean weight is 1 (keeps the LR meaningful)
        self.weights = np.ones(self.n, np.float32)
        self.weights[self.subset] = w * (len(w) / max(w.sum(), 1e-8))
        return True

    def begin_epoch(self) -> np.ndarray:
        # Device shuffle of the frozen subset; the subset length only
        # changes at a reselection, so the jitted permutation retraces at
        # most once per R epochs.  One device_get = the epoch's host sync.
        self._key, sub = jax.random.split(self._key)
        order = jax.device_get(
            planops.device_permutation(sub, len(self.subset)))
        return self.subset[np.asarray(order)]

    def batches(self, epoch_indices: np.ndarray, batch_size: int) -> Iterator[np.ndarray]:
        for start in range(0, len(epoch_indices) - batch_size + 1, batch_size):
            yield epoch_indices[start : start + batch_size]


@register_strategy("gradmatch")
class GradMatchStrategy(SampleStrategy):
    """OMP subset selection; features arrive via the ``prepare`` hook."""

    config_cls, config_field = GradMatchConfig, "gradmatch"

    def __init__(self, num_samples: int, config: GradMatchConfig | None = None,
                 seed: int = 0, num_classes: int | None = None):
        super().__init__(num_samples, config, seed)
        # num_classes may be omitted only while no reselection ever runs
        # (registry smoke-builds); prepare() enforces it the moment features
        # arrive, since single-class OMP would silently change the science.
        self._num_classes = num_classes
        self._inner = GradMatchSampler(num_samples, num_classes or 1,
                                       config, seed)

    def prepare(self, epoch: int, feats_fn: FeatsFn | None = None) -> None:
        if feats_fn is None or epoch % self._inner.config.interval != 0:
            return
        if self._num_classes is None:
            raise ValueError(
                "gradmatch needs num_classes for its per-class OMP "
                "decomposition — pass num_classes to make_strategy/Trainer")
        feats, labels = feats_fn()
        self._inner.maybe_reselect(epoch, feats, labels)

    def plan(self, epoch: int) -> EpochPlan:
        return EpochPlan(epoch=epoch,
                         visible_indices=self._inner.begin_epoch(),
                         host_syncs=1)

    def batch_weights(self, indices: np.ndarray) -> np.ndarray:
        return self._inner.weights[indices]

    def state_dict(self) -> dict:
        return {"arrays": {"subset": self._inner.subset,
                           "weights": self._inner.weights,
                           "rng_key": planops.key_data(self._inner._key)},
                "host": {"rng_impl": planops.KEY_IMPL}}

    def load_state_dict(self, state: dict) -> None:
        self._inner.subset = np.asarray(state["arrays"]["subset"])
        self._inner.weights = np.asarray(state["arrays"]["weights"], np.float32)
        # restore_key also migrates pre-PlanOps checkpoints (host numpy RNG).
        self._inner._key = planops.restore_key(state, self.seed, "gradmatch")
