"""Per-sample bookkeeping state for KAKURENBO and related methods.

The paper (Sec. 3.4) keeps, for every sample n in the dataset:
  - a (possibly lagging) loss  l_n,
  - prediction accuracy  PA_n  (was the sample predicted correctly?),
  - prediction confidence PC_n (max softmax probability),
all refreshed from the *training* forward pass for visible samples and from a
forward-only refresh pass for hidden samples.  Here that state is a pytree of
``(N,)`` device arrays; under the mesh-sharded trainer
(``TrainConfig.mesh_shape``) it lives row-sharded over the ``("data",)``
mesh axis for the whole run — the scatter below and the selection plan
(``core/selection.py``) both operate on the sharded layout, and the state
only crosses the host boundary at the per-epoch ``EpochPlan``
materialisation (see ``docs/architecture.md``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SampleState:
    """State for the N samples of a dataset.

    Attributes:
      loss:    (N,) f32 — lagging loss from the last epoch the sample was seen.
      pa:      (N,) bool — correctly predicted last time it was seen.
      pc:      (N,) f32 — prediction confidence (max softmax prob).
      hidden:  (N,) bool — hidden during the *current* epoch.
      seen:    (N,) i32 — epoch index at which loss/pa/pc were last refreshed
               (-1 = never; such samples are always treated as important).
      forget_events: (N,) i32 — count of correct->incorrect transitions
               (used by the FORGET baseline; free to maintain).
      prev_correct: (N,) bool — correctness at the previous observation
               (for forgetting-event detection).
    """

    loss: jax.Array
    pa: jax.Array
    pc: jax.Array
    hidden: jax.Array
    seen: jax.Array
    forget_events: jax.Array
    prev_correct: jax.Array

    @property
    def num_samples(self) -> int:
        return self.loss.shape[0]


def init_sample_state(num_samples: int, init_loss: float = 1e9) -> SampleState:
    """Fresh state: everything visible, infinitely-important losses.

    ``init_loss`` is large so that never-seen samples sort as maximally
    important and are never hidden (the paper hides *low*-loss samples).
    """
    n = num_samples
    return SampleState(
        loss=jnp.full((n,), init_loss, jnp.float32),
        pa=jnp.zeros((n,), bool),
        pc=jnp.zeros((n,), jnp.float32),
        hidden=jnp.zeros((n,), bool),
        seen=jnp.full((n,), -1, jnp.int32),
        forget_events=jnp.zeros((n,), jnp.int32),
        prev_correct=jnp.zeros((n,), bool),
    )


def scatter_observations(
    state: SampleState,
    indices: jax.Array,
    loss: jax.Array,
    pa: jax.Array,
    pc: jax.Array,
    epoch: jax.Array | int,
    valid: jax.Array | None = None,
) -> SampleState:
    """Record (loss, PA, PC) for the samples at ``indices``.

    This is the "lagging loss" update (paper Sec. 3.4): called once per
    training batch with metrics computed *during* the forward pass, and once
    per hidden-refresh batch at epoch end.  Duplicate indices are allowed
    (last write wins under XLA scatter semantics, matching the paper where a
    sample is observed at most once per epoch anyway).

    ``valid`` is the numeric guard's score-quarantine mask
    (``train/guard.py``): entries where it is False scatter the sample's
    *existing* values back — loss/PA/PC, the ``seen`` epoch, the
    forgetting-event state all hold — so a non-finite observation is a
    bit-exact no-op for that sample and the next epoch plan stays finite.
    ``None`` (the default) is the unguarded path, traced exactly as before.
    (With duplicate indices an invalid later duplicate restores the
    *pre-batch* value; irrelevant in practice, since a sample is observed
    at most once per epoch.)

    Sharding: the update is scatter-only (no cross-sample reductions) plus
    O(B) gathers, so it is GSPMD-safe — with ``state`` row-sharded over the
    data axes and ``indices`` arbitrary global ids, the partitioner lowers
    each scatter to an O(B) gather of the updates plus shard-local writes,
    which is exactly the schedule a hand-written shard_map version would
    use.  The mesh trainer relies on this to keep the fused observe inside
    its jitted step without a second, shard-offset state contract.
    """
    # A forgetting event (FORGET baseline) is a correct -> incorrect flip.
    was_correct = state.prev_correct[indices]
    epoch = jnp.asarray(epoch, jnp.int32)
    if valid is None:
        forget_inc = (was_correct & ~pa).astype(jnp.int32)
        seen_val = jnp.broadcast_to(epoch, indices.shape)
    else:
        loss = jnp.where(valid, loss, state.loss[indices])
        pa = jnp.where(valid, pa, state.pa[indices])
        pc = jnp.where(valid, pc, state.pc[indices])
        forget_inc = jnp.where(valid, was_correct & ~pa,
                               False).astype(jnp.int32)
        seen_val = jnp.where(valid, epoch, state.seen[indices])
        pa_prev = jnp.where(valid, pa, state.prev_correct[indices])
    return SampleState(
        loss=state.loss.at[indices].set(loss.astype(jnp.float32)),
        pa=state.pa.at[indices].set(pa),
        pc=state.pc.at[indices].set(pc.astype(jnp.float32)),
        hidden=state.hidden,
        seen=state.seen.at[indices].set(seen_val),
        forget_events=state.forget_events.at[indices].add(forget_inc),
        prev_correct=state.prev_correct.at[indices].set(
            pa if valid is None else pa_prev),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainCarry:
    """The full device-resident train state threaded through a scanned epoch.

    This is the ``lax.scan`` carry of the scanned epoch engine
    (``train/engines.py``): params, optimizer state, the error-feedback
    residual (None without compression) and the strategy's device state
    (``SampleState``, or a fused-select state pytree; None for stateless
    strategies) ride through K train steps per dispatch, and per-step
    (loss, backward-count) scalars come back as the scan's stacked outputs
    — so the whole block costs one dispatch and the losses one
    ``device_get`` per epoch.  The host-loop engine threads the same objects
    through its per-batch jitted step; sharing the structure is what keeps
    the two engines' donation/restart contracts identical (a crash between
    scan blocks leaves a fully live carry to hand back for
    checkpoint-on-fault).  ``gstate`` is the numeric guard's counter pytree
    (``train/guard.py::GuardState``; None with ``guard_policy="off"``, so
    the unguarded carry is structurally unchanged).
    """

    params: Any
    opt_state: Any
    ef: Any
    sstate: Any
    gstate: Any = None


def with_hidden(state: SampleState, hidden: jax.Array) -> SampleState:
    return dataclasses.replace(state, hidden=hidden)


def state_summary(state: SampleState) -> dict[str, Any]:
    """Host-side summary used for logging / checksum in checkpoints."""
    return {
        "num_samples": int(state.num_samples),
        "num_hidden": int(jnp.sum(state.hidden)),
        "mean_loss_seen": float(
            jnp.mean(jnp.where(state.seen >= 0, state.loss, 0.0))
        ),
        "num_seen": int(jnp.sum(state.seen >= 0)),
    }
