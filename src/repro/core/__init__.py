"""KAKURENBO core: adaptive sample hiding + the paper's baselines."""
from repro.core.state import (  # noqa: F401
    SampleState, init_sample_state, scatter_observations, with_hidden,
)
from repro.core.selection import (  # noqa: F401
    select_hidden, select_hidden_sort, select_hidden_histogram,
    histogram_threshold, HIST_BINS,
)
from repro.core.schedule import (  # noqa: F401
    FractionSchedule, LRSchedule, kakurenbo_lr, linear_scaling_rule,
)
from repro.core.kakurenbo import (  # noqa: F401
    KakurenboConfig, KakurenboSampler, EpochPlan,
)
from repro.core.iswr import ISWRConfig, ISWRSampler  # noqa: F401
from repro.core.forget import ForgetConfig, ForgetSampler  # noqa: F401
from repro.core.selective_backprop import SBConfig, SelectiveBackprop  # noqa: F401
from repro.core.gradmatch import GradMatchConfig, GradMatchSampler  # noqa: F401
from repro.core.infobatch import InfoBatchConfig, InfoBatchSampler  # noqa: F401
