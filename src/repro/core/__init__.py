"""KAKURENBO core: adaptive sample hiding + the paper's baselines.

All selection methods implement the unified ``SampleStrategy`` protocol and
are discoverable through the registry (``make_strategy``/``STRATEGIES``);
the legacy sampler classes remain exported for direct, low-level use.
"""
from repro.core import planops  # noqa: F401
from repro.core.planops import strategy_key  # noqa: F401
from repro.core.state import (  # noqa: F401
    SampleState, TrainCarry, init_sample_state, scatter_observations,
    with_hidden,
)
from repro.core.selection import (  # noqa: F401
    select_hidden, select_hidden_sort, select_hidden_histogram,
    histogram_threshold, HIST_BINS, SELECTION_METHODS,
)
from repro.core.schedule import (  # noqa: F401
    FractionSchedule, LRSchedule, kakurenbo_lr, linear_scaling_rule,
)
from repro.core.strategy import (  # noqa: F401
    EpochPlan, SampleStrategy, STRATEGIES, available_strategies,
    make_strategy, register_strategy,
)
from repro.core.kakurenbo import (  # noqa: F401
    KakurenboConfig, KakurenboSampler, KakurenboStrategy,
)
from repro.core.baseline import BaselineStrategy, RandomStrategy  # noqa: F401
from repro.core.iswr import ISWRConfig, ISWRSampler, ISWRStrategy  # noqa: F401
from repro.core.forget import ForgetConfig, ForgetSampler, ForgetStrategy  # noqa: F401
from repro.core.selective_backprop import (  # noqa: F401
    SBConfig, SBStrategy, SelectiveBackprop,
)
from repro.core.gradmatch import (  # noqa: F401
    GradMatchConfig, GradMatchSampler, GradMatchStrategy,
)
from repro.core.infobatch import (  # noqa: F401
    InfoBatchConfig, InfoBatchSampler, InfoBatchStrategy,
)
