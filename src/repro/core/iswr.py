"""Importance Sampling With Replacement (ISWR) baseline [Katharopoulos'18].

Each epoch draws N samples *with replacement* with probability proportional
to the (lagging) per-sample loss; the model therefore sees the same number of
samples per epoch as the baseline (paper Sec. 4, "ISWR").  Optional unbiasing
weights w_i = 1/(N p_i) are available (the paper's plain variant leaves them
off, matching [11]'s practical recipe with loss-proportional probabilities).

Planning is device-resident (``core/planops.py``): the draw probabilities
and the inverse-CDF with-replacement draw are one jitted plan step over the
device ``SampleState``, driven by a checkpointable PRNG key; the epoch's
index list and probabilities cross to the host in a single
``jax.device_get``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import planops
from repro.core.state import SampleState, init_sample_state, scatter_observations
from repro.core.strategy import EpochPlan, SampleStrategy, register_strategy
from repro.dist.sharding import ParallelCtx


@dataclasses.dataclass
class ISWRConfig:
    smoothing: float = 1e-3   # additive smoothing so unseen/zero-loss samples
                              # keep a nonzero draw probability
    unbiased: bool = False    # multiply per-sample loss by 1/(N p_i)


@functools.partial(jax.jit, static_argnames=("mesh",))
def _plan_step(state: SampleState, key: jax.Array, smoothing: float, *,
               mesh=None):
    """Device epoch plan: loss-proportional probabilities + N draws."""
    p = planops.importance_probs(state.loss, state.seen >= 0, smoothing,
                                 mesh=mesh)
    return planops.with_replacement(key, p, mesh=mesh), p


class ISWRSampler:
    def __init__(self, num_samples: int, config: ISWRConfig | None = None,
                 seed: int = 0, ctx: ParallelCtx | None = None):
        self.config = config or ISWRConfig()
        self.ctx = ctx or ParallelCtx()
        self.ctx.check_rows(num_samples)
        self.state: SampleState = self.ctx.shard_rows(
            init_sample_state(num_samples, init_loss=1.0))
        self._key = self.ctx.replicate(planops.strategy_key(seed, "iswr"))
        self._observe = jax.jit(scatter_observations)
        self._last_p = np.full(num_samples, 1.0 / num_samples)

    def begin_epoch(self, epoch: int) -> np.ndarray:
        """Return N with-replacement indices for this epoch."""
        self._key, sub = jax.random.split(self._key)
        draw, p = _plan_step(self.state, sub, self.config.smoothing,
                             mesh=self.ctx.mesh)
        # The single host sync of the epoch: the draw + its probabilities
        # (kept for the optional unbiasing weight lookup).
        draw, p = jax.device_get((draw, p))
        self._last_p = np.asarray(p)
        return np.asarray(draw)

    def sample_weights(self, indices: np.ndarray) -> np.ndarray:
        if not self.config.unbiased:
            return np.ones(len(indices), np.float32)
        n = self.state.num_samples
        return (1.0 / (n * self._last_p[indices])).astype(np.float32)

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        self.state = self._observe(self.state, jnp.asarray(indices), loss, pa,
                                   pc, epoch)

    def batches(self, epoch_indices: np.ndarray, batch_size: int) -> Iterator[np.ndarray]:
        for start in range(0, len(epoch_indices) - batch_size + 1, batch_size):
            yield epoch_indices[start : start + batch_size]


@register_strategy("iswr")
class ISWRStrategy(SampleStrategy):
    """With-replacement importance sampling behind the strategy protocol."""

    config_cls, config_field = ISWRConfig, "iswr"
    fused_observe = staticmethod(scatter_observations)

    def __init__(self, num_samples: int, config: ISWRConfig | None = None,
                 seed: int = 0, ctx: ParallelCtx | None = None):
        super().__init__(num_samples, config, seed)
        self._inner = ISWRSampler(num_samples, config, seed, ctx=ctx)

    @property
    def state(self) -> SampleState:
        return self._inner.state

    def get_device_state(self) -> SampleState:
        return self._inner.state

    def set_device_state(self, state: SampleState) -> None:
        self._inner.state = state

    def plan(self, epoch: int) -> EpochPlan:
        # begin_epoch materialises the draw with one device_get: 1 host sync.
        return EpochPlan(epoch=epoch,
                         visible_indices=self._inner.begin_epoch(epoch),
                         host_syncs=1)

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        self._inner.observe(indices, loss, pa, pc, epoch)

    def batch_weights(self, indices: np.ndarray) -> np.ndarray:
        return self._inner.sample_weights(indices)

    def state_dict(self) -> dict:
        # _last_p is not saved: begin_epoch() recomputes it from the state
        # before any weight lookup after a restore.
        return {"arrays": {"state": self._inner.state,
                           "rng_key": planops.key_data(self._inner._key)},
                "host": {"rng_impl": planops.KEY_IMPL}}

    def load_state_dict(self, state: dict) -> None:
        self._inner.state = self._inner.ctx.shard_rows(
            jax.tree.map(jnp.asarray, state["arrays"]["state"]))
        # restore_key also migrates pre-PlanOps checkpoints (host numpy RNG).
        self._inner._key = self._inner.ctx.replicate(
            planops.restore_key(state, self.seed, "iswr"))
