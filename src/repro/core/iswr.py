"""Importance Sampling With Replacement (ISWR) baseline [Katharopoulos'18].

Each epoch draws N samples *with replacement* with probability proportional
to the (lagging) per-sample loss; the model therefore sees the same number of
samples per epoch as the baseline (paper Sec. 4, "ISWR").  Optional unbiasing
weights w_i = 1/(N p_i) are available (the paper's plain variant leaves them
off, matching [11]'s practical recipe with loss-proportional probabilities).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state import SampleState, init_sample_state, scatter_observations
from repro.core.strategy import (
    EpochPlan, SampleStrategy, register_strategy, rng_state, set_rng_state,
)


@dataclasses.dataclass
class ISWRConfig:
    smoothing: float = 1e-3   # additive smoothing so unseen/zero-loss samples
                              # keep a nonzero draw probability
    unbiased: bool = False    # multiply per-sample loss by 1/(N p_i)


class ISWRSampler:
    def __init__(self, num_samples: int, config: ISWRConfig | None = None,
                 seed: int = 0):
        self.config = config or ISWRConfig()
        self.state: SampleState = init_sample_state(num_samples, init_loss=1.0)
        self._rng = np.random.default_rng(seed)
        self._observe = jax.jit(scatter_observations)
        self._last_p = np.full(num_samples, 1.0 / num_samples)

    def begin_epoch(self, epoch: int) -> np.ndarray:
        """Return N with-replacement indices for this epoch."""
        loss = np.asarray(self.state.loss)
        # Never-seen samples get the mean seen loss (neutral importance).
        seen = np.asarray(self.state.seen) >= 0
        fill = loss[seen].mean() if seen.any() else 1.0
        loss = np.where(seen, loss, fill) + self.config.smoothing
        p = loss / loss.sum()
        self._last_p = p
        n = self.state.num_samples
        return self._rng.choice(n, size=n, replace=True, p=p)

    def sample_weights(self, indices: np.ndarray) -> np.ndarray:
        if not self.config.unbiased:
            return np.ones(len(indices), np.float32)
        n = self.state.num_samples
        return (1.0 / (n * self._last_p[indices])).astype(np.float32)

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        self.state = self._observe(self.state, jnp.asarray(indices), loss, pa,
                                   pc, epoch)

    def batches(self, epoch_indices: np.ndarray, batch_size: int) -> Iterator[np.ndarray]:
        for start in range(0, len(epoch_indices) - batch_size + 1, batch_size):
            yield epoch_indices[start : start + batch_size]


@register_strategy("iswr")
class ISWRStrategy(SampleStrategy):
    """With-replacement importance sampling behind the strategy protocol."""

    config_cls, config_field = ISWRConfig, "iswr"
    fused_observe = staticmethod(scatter_observations)

    def __init__(self, num_samples: int, config: ISWRConfig | None = None,
                 seed: int = 0):
        super().__init__(num_samples, config, seed)
        self._inner = ISWRSampler(num_samples, config, seed)

    @property
    def state(self) -> SampleState:
        return self._inner.state

    def get_device_state(self) -> SampleState:
        return self._inner.state

    def set_device_state(self, state: SampleState) -> None:
        self._inner.state = state

    def plan(self, epoch: int) -> EpochPlan:
        # begin_epoch materialises the loss array for the draw: 1 host sync.
        return EpochPlan(epoch=epoch,
                         visible_indices=self._inner.begin_epoch(epoch),
                         host_syncs=1)

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        self._inner.observe(indices, loss, pa, pc, epoch)

    def batch_weights(self, indices: np.ndarray) -> np.ndarray:
        return self._inner.sample_weights(indices)

    def state_dict(self) -> dict:
        # _last_p is not saved: begin_epoch() recomputes it from the state
        # before any weight lookup after a restore.
        return {"arrays": {"state": self._inner.state},
                "host": {"rng": rng_state(self._inner._rng)}}

    def load_state_dict(self, state: dict) -> None:
        self._inner.state = jax.tree.map(jnp.asarray, state["arrays"]["state"])
        set_rng_state(self._inner._rng, state["host"]["rng"])
