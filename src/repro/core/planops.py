"""PlanOps: the shared device-resident epoch-planning library.

Every strategy's ``plan()`` used to be a private pile of host numpy —
``np.random.default_rng`` shuffles, ``np.argsort`` ranks, host-side masks —
which forced the seven comparison baselines onto the slower host loop while
KAKURENBO itself planned on device (PR 2-4).  This module extracts that
planning math into composable jitted ops over ``(loss, confidence, aux)``
score arrays so *every* strategy plans the same way the KAKURENBO
``_plan_step`` does:

- one checkpointable device PRNG key per strategy (``strategy_key`` — the
  single seeding convention, replacing the scattered ``seed`` / ``seed + 1``
  host generators),
- selection as pure array ops (``threshold_mask`` / ``topk_hide`` /
  ``weighted_keep`` / ``stable_rank_order`` / ``with_replacement``), sharing
  the histogram-CDF core — and its Pallas kernel path
  (``kernels/threshold_select.py``) — with ``core/selection.py``,
- the epoch order as one fixed-shape permutation (``masked_order``: a
  uniform shuffle stable-sorted so masked-out samples trail), materialised
  to the host ``EpochPlan`` with a single ``jax.device_get``.

Sharding: each op takes an optional static ``mesh``.  With a mesh, score
inputs are first constrained to a *replicated* layout, so the reduction
trees (means, cumsums, sorts) are exactly the single-device computation on
every shard — plans are bit-identical across mesh sizes, the same guarantee
the chunk-major gradient fold gives the train step.  This is the O(N)-gather
regime of the paper-faithful ``"sort"`` plan; the O(bins)-communication
regime stays available through ``histogram_masks``, which runs unchanged
inside a ``shard_map`` with ``axis_names`` (how ``core/selection.py`` and
``KakurenboSampler._plan_step`` use it).

Checkpointing: keys serialize through ``key_data``/``load_key``.
``restore_key`` also accepts the *legacy* checkpoint format (a numpy
``Generator`` state under ``host["rng"]``): the shim derives the device key
deterministically from the stored generator, so pre-PlanOps strategy state
dicts still restore — the resumed run is deterministic, but continues on the
device RNG stream rather than the retired numpy one (see
``docs/architecture.md``, "Checkpoint migration").
"""
from __future__ import annotations

import functools
import zlib

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

#: PRNG implementation pinned for checkpoint stability: key data saved on
#: one jax version must restore on another.
KEY_IMPL = "threefry2x32"

#: Histogram resolution of the threshold paths (shared with core/selection).
HIST_BINS = 512


# ---------------------------------------------------------------------------
# Keys: one seeding convention + checkpoint/migration helpers
# ---------------------------------------------------------------------------


def strategy_key(seed: int, name: str) -> jax.Array:
    """The device PRNG key for strategy ``name`` at ``seed``.

    Folds a stable hash of the name into the seed key, so strategies sharing
    one config seed draw from decorrelated streams — the convention that
    replaces the ad-hoc ``seed`` / ``seed + 1`` numpy generators.
    """
    base = jax.random.key(seed, impl=KEY_IMPL)
    return jax.random.fold_in(base, zlib.crc32(name.encode("utf-8")) & 0x7FFFFFFF)


def key_data(key: jax.Array) -> jax.Array:
    """Serializable uint32 view of a key (checkpoint leaf)."""
    return jax.random.key_data(key)


def load_key(data) -> jax.Array:
    """Rebuild a key from ``key_data`` output."""
    return jax.random.wrap_key_data(jnp.asarray(data, jnp.uint32),
                                    impl=KEY_IMPL)


def migrate_legacy_rng(host_state: dict, seed: int, name: str) -> jax.Array:
    """Derive a device key from a pre-PlanOps numpy ``Generator`` state.

    Deterministic: the same legacy checkpoint always yields the same key (two
    uint32 words drawn from the restored generator).  The numpy stream itself
    is retired — a migrated run resumes deterministically but not on the
    bit-trajectory the legacy host planner would have produced.
    """
    try:
        g = np.random.default_rng(0)
        g.bit_generator.state = host_state
        words = g.integers(0, 2 ** 32, size=2, dtype=np.int64).astype(np.uint32)
    except (KeyError, TypeError, ValueError):
        # Unrecognisable legacy payload: fall back to the seed convention.
        return strategy_key(seed, name)
    return load_key(words)


def restore_key(state: dict, seed: int, name: str,
                leaf: str = "rng_key") -> jax.Array:
    """Key from a strategy ``state_dict`` — current or legacy format.

    Current checkpoints carry ``arrays[leaf]`` (``key_data``); legacy ones
    carry a numpy generator state under ``host["rng"]`` and are migrated via
    ``migrate_legacy_rng``.
    """
    arrays = state.get("arrays") or {}
    host = state.get("host") or {}
    if leaf in arrays:
        return load_key(arrays[leaf])
    if "rng" in host:
        return migrate_legacy_rng(host["rng"], seed, name)
    raise ValueError(
        f"state dict for {name!r} has neither arrays[{leaf!r}] nor a legacy "
        "host['rng'] entry — cannot restore the plan RNG")


# ---------------------------------------------------------------------------
# Sharding helper
# ---------------------------------------------------------------------------


def _rep(x, mesh):
    """Constrain to a replicated layout under ``mesh`` (identity otherwise).

    Replication is what makes plan math mesh-size-invariant: reductions over
    a replicated array are the single-device computation on every shard, so
    a ``(8,)`` mesh produces bit-identical plans to ``(1,)``.
    """
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P()))


# ---------------------------------------------------------------------------
# Permutations / ordering
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n",))
def device_permutation(key: jax.Array, n: int) -> jax.Array:
    """Uniform permutation of ``range(n)`` — the epoch shuffle."""
    return jax.random.permutation(key, n)


@functools.partial(jax.jit, static_argnames=("mesh",))
def masked_order(key: jax.Array, mask: jax.Array, *, mesh=None):
    """Shuffled epoch order with masked-out samples trailing.

    Returns ``(order, num_masked)``: ``order`` is a uniform permutation
    stable-sorted by ``mask`` so the kept (False) entries come first in
    shuffled order — one fixed-shape array instead of two ragged ones, the
    same trick ``KakurenboSampler._plan_step`` uses for its visible/hidden
    split.  ``order[:n - num_masked]`` is the epoch's visible index list.
    """
    mask = _rep(mask, mesh)
    n = mask.shape[0]
    perm = jax.random.permutation(key, n)
    order = perm[jnp.argsort(mask[perm], stable=True)]
    return order, jnp.sum(mask).astype(jnp.int32)


@jax.jit
def stable_rank_order(scores: jax.Array) -> jax.Array:
    """Rank of each sample under a *stable* ascending sort (0 = smallest).

    Ties break by index — FORGET's fewest-events-first order (Toneva et al.),
    where the tie-break is part of the published recipe.  This is the
    O(N log N) oracle; plans that only need a rank *window* go through
    ``topk_hide`` / ``sort_high_mask``, which use the O(N) count-then-select
    path of ``kernels/threshold_select.py`` and are asserted bit-identical
    to this ranking.
    """
    n = scores.shape[0]
    order = jnp.argsort(scores, stable=True)
    return jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))


@functools.partial(jax.jit, static_argnames=("mesh",))
def topk_hide(scores: jax.Array, k: jax.Array, *, mesh=None) -> jax.Array:
    """Mask of the ``k`` smallest scores (stable ties) — FORGET's prune set.

    Bit-identical to ``stable_rank_order(scores) < k`` (the retained
    oracle), but via the radix count-then-select of
    ``kernels/threshold_select.py``: a handful of O(N) histogram passes
    instead of materialising a full argsort — the Table-1 selection cost
    the paper calls out, removed from the plan step.
    """
    from repro.kernels import ops as kernel_ops
    scores = _rep(scores, mesh)
    return kernel_ops.rank_select(scores, k)


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("mesh",))
def importance_probs(loss: jax.Array, valid: jax.Array, smoothing: float,
                     *, mesh=None) -> jax.Array:
    """Loss-proportional draw probabilities (ISWR).

    Never-seen samples take the mean seen loss (neutral importance, 1.0 when
    nothing is seen yet); ``smoothing`` keeps zero-loss samples drawable.

    Defense in depth against numeric faults (train/guard.py keeps them out
    of ``SampleState`` upstream): a non-finite loss is treated as not valid
    — it takes the neutral fill instead of poisoning the mean/CDF.  Free
    when everything is finite (the mask is unchanged bit for bit).
    """
    loss, valid = _rep(loss, mesh), _rep(valid, mesh)
    valid = valid & jnp.isfinite(loss)
    cnt = jnp.sum(valid)
    fill = jnp.where(
        cnt > 0,
        jnp.sum(jnp.where(valid, loss, 0.0)) / jnp.maximum(cnt, 1), 1.0)
    smoothed = jnp.where(valid, loss, fill) + smoothing
    return smoothed / jnp.sum(smoothed)


@functools.partial(jax.jit, static_argnames=("mesh",))
def with_replacement(key: jax.Array, p: jax.Array, *, mesh=None) -> jax.Array:
    """N categorical draws *with replacement* from probabilities ``p`` (N,).

    Inverse-CDF sampling: O(N log N), fixed shapes — the device replacement
    for ``np.random.Generator.choice(..., replace=True, p=p)``.
    """
    p = _rep(p, mesh)
    n = p.shape[0]
    cdf = jnp.cumsum(p)
    u = jax.random.uniform(key, (n,), jnp.float32, 0.0, cdf[-1])
    idx = jnp.searchsorted(cdf, u, side="right")
    return jnp.clip(idx, 0, n - 1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("mesh",))
def weighted_keep(key: jax.Array, loss: jax.Array, valid: jax.Array,
                  prune_ratio: float, *, mesh=None):
    """InfoBatch soft pruning: ``(prune_mask, weights)``.

    Randomly prunes fraction ``prune_ratio`` of the *below-mean* valid
    samples and up-weights every kept below-mean sample by ``1/(1-r)`` so
    the expected gradient is unbiased.  With nothing valid the mask is empty
    and the weights are uniform.  Non-finite losses are treated as not
    valid (never pruned, weight 1.0) so they cannot poison the mean.
    """
    loss, valid = _rep(loss, mesh), _rep(valid, mesh)
    valid = valid & jnp.isfinite(loss)
    cnt = jnp.sum(valid)
    mean = jnp.sum(jnp.where(valid, loss, 0.0)) / jnp.maximum(cnt, 1)
    below = valid & (loss < mean)
    u = jax.random.uniform(key, loss.shape)
    prune = below & (u < prune_ratio)
    weights = jnp.where(below & ~prune, 1.0 / (1.0 - prune_ratio),
                        1.0).astype(jnp.float32)
    return prune, weights


# ---------------------------------------------------------------------------
# Threshold selection (the histogram-CDF core shared with core/selection)
# ---------------------------------------------------------------------------


def _axis_reduce(x, axis_names, op):
    for ax in axis_names:
        x = op(x, ax)
    return x


def sort_low_mask(loss: jax.Array, fraction: jax.Array) -> jax.Array:
    """Candidate mask of the ``floor(fraction*N)`` lowest losses (argsort).

    The paper-faithful O(N log N) path; under GSPMD it is a global argsort
    (the O(N) gather the paper's own method costs).
    """
    n = loss.shape[0]
    fraction = jnp.asarray(fraction, jnp.float32)
    num_hide = jnp.floor(fraction * n).astype(jnp.int32)
    order = jnp.argsort(loss)
    rank = jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
    return rank < num_hide


def sort_high_mask(loss: jax.Array, valid: jax.Array,
                   fraction: float) -> jax.Array:
    """Mask of the highest-loss ``fraction`` among valid samples (DropTop).

    Invalid samples must not occupy the top-rank window (their sentinel
    losses sort above every real loss), so they rank below everything.
    Non-finite losses are treated as invalid — a NaN would otherwise sort
    into the top tail and claim a drop slot.

    Routed through the count-then-select path (high variant) — bit-identical
    to the old ``argsort`` ranking (``sort_high_mask_argsort``, kept as the
    parity oracle) without materialising it.
    """
    from repro.kernels import ops as kernel_ops
    valid = valid & jnp.isfinite(loss)
    n = loss.shape[0]
    num_top = jnp.floor(jnp.asarray(fraction) * n).astype(jnp.int32)
    keyed = jnp.where(valid, loss, -jnp.inf)
    return kernel_ops.rank_select(keyed, num_top, high=True) & valid


def sort_high_mask_argsort(loss: jax.Array, valid: jax.Array,
                           fraction: float) -> jax.Array:
    """The pre-radix O(N log N) ``sort_high_mask`` — the parity oracle."""
    valid = valid & jnp.isfinite(loss)
    n = loss.shape[0]
    num_top = jnp.floor(jnp.asarray(fraction) * n).astype(jnp.int32)
    order_top = jnp.argsort(jnp.where(valid, loss, -jnp.inf))
    rank_top = jnp.zeros((n,), jnp.int32).at[order_top].set(
        jnp.arange(n, dtype=jnp.int32))
    return (rank_top >= n - num_top) & valid


def histogram_masks(
    loss: jax.Array,
    valid: jax.Array,
    low_fraction: jax.Array,
    high_fraction: float = 0.0,
    *,
    bins: int = HIST_BINS,
    axis_names: tuple[str, ...] = (),
    use_kernel: bool = False,
):
    """Histogram-CDF threshold masks: ``(low_mask, high_mask)``.

    One O(N) pass builds the loss histogram (optionally with the Pallas
    streaming kernels of ``kernels/threshold_select.py``); the CDF walk
    yields the lowest-loss candidate mask for ``low_fraction`` and — when
    ``high_fraction > 0`` — the mirrored top-tail mask (DropTop).  Inside a
    ``shard_map`` over ``axis_names`` the histogram is psum'd, so every shard
    derives the same global thresholds from O(bins) communicated scalars.

    The boundary bin is included only if excluding it would under-fill by
    more than half its population — overshoot is bounded by one bin, and
    undershoot is always legal (F is a ceiling, paper Sec. 3.1).

    Non-finite losses count as invalid: one NaN/inf would otherwise stretch
    the lo/hi span (collapsing every real loss into one bin) or poison the
    bin index.  Free when everything is finite — the masks are bit-exact.
    """
    n_local = loss.shape[0]
    valid = valid & jnp.isfinite(loss)
    low_fraction = jnp.asarray(low_fraction, jnp.float32)

    psum = functools.partial(_axis_reduce, axis_names=axis_names,
                             op=jax.lax.psum)
    pmin = functools.partial(_axis_reduce, axis_names=axis_names,
                             op=jax.lax.pmin)
    pmax = functools.partial(_axis_reduce, axis_names=axis_names,
                             op=jax.lax.pmax)

    n_global = psum(jnp.asarray(n_local, jnp.float32))
    num_hide = jnp.floor(low_fraction * n_global).astype(jnp.int32)
    big = jnp.float32(3.4e38)
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        lo, hi = kernel_ops.loss_minmax(loss, valid)
    else:
        lo = jnp.min(jnp.where(valid, loss, big))
        hi = jnp.max(jnp.where(valid, loss, -big))
    lo = pmin(lo)
    hi = pmax(hi)
    lo = jnp.minimum(lo, hi)  # degenerate all-invalid shards

    span = jnp.maximum(hi - lo, 1e-12)
    idx = jnp.clip(((loss - lo) / span * bins).astype(jnp.int32), 0, bins - 1)
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        hist = kernel_ops.loss_histogram(loss, valid, lo, hi, bins)
    else:
        hist = jnp.zeros((bins,), jnp.int32).at[idx].add(
            valid.astype(jnp.int32))
    hist = psum(hist)
    cdf = jnp.cumsum(hist)
    b = jnp.clip(jnp.searchsorted(cdf, num_hide, side="left"), 0, bins - 1)
    below = jnp.where(b > 0, cdf[jnp.maximum(b - 1, 0)], 0)
    include_b = (num_hide - below) * 2 >= hist[b]
    low_mask = jnp.where(include_b, idx <= b, idx < b) & valid

    high_mask = None
    if high_fraction > 0.0:
        num_top = jnp.floor(
            jnp.asarray(high_fraction, jnp.float32) * n_global
        ).astype(jnp.int32)
        rcdf = jnp.cumsum(hist[::-1])  # rcdf[j] = count in the top j+1 bins
        bt = jnp.clip(jnp.searchsorted(rcdf, num_top, side="left"), 0,
                      bins - 1)
        b_top = bins - 1 - bt
        above = jnp.where(bt > 0, rcdf[jnp.maximum(bt - 1, 0)], 0)
        include_bt = (num_top - above) * 2 >= hist[b_top]
        high_mask = jnp.where(include_bt, idx >= b_top, idx > b_top) & valid
    return low_mask, high_mask


@functools.partial(
    jax.jit, static_argnames=("method", "bins", "use_kernel", "mesh"))
def threshold_mask(
    loss: jax.Array,
    valid: jax.Array,
    fraction: jax.Array | float,
    *,
    method: str = "sort",
    bins: int = HIST_BINS,
    use_kernel: bool = False,
    mesh=None,
) -> jax.Array:
    """Lowest-loss candidate mask, by any selection method.

    The generic entry point for strategies and tests: ``"sort"`` ranks
    globally, ``"histogram"``/``"histogram_pallas"`` walk the histogram CDF
    (``use_kernel`` is implied by the pallas method name).  For the O(bins)
    cross-shard regime call ``histogram_masks`` inside your own shard_map
    (as ``core/selection.py`` does); here a mesh only adds the replication
    constraint.
    """
    loss, valid = _rep(loss, mesh), _rep(valid, mesh)
    if method == "sort":
        return sort_low_mask(loss, fraction)
    if method in ("histogram", "histogram_pallas"):
        low, _ = histogram_masks(
            loss, valid, fraction, bins=bins,
            use_kernel=use_kernel or method == "histogram_pallas")
        return low
    raise ValueError(f"unknown selection method {method!r}")
