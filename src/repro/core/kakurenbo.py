"""KAKURENBO epoch orchestration (paper Fig. 1).

Per epoch e:
  B.1/B.2  rank samples by lagging loss, hide lowest-loss fraction <= F_e
  B.3      move back candidates not (correct & PC >= tau) last epoch
  C        train on the visible set with uniform w/o-replacement sampling;
           LR multiplied by 1/(1-F*_e) (Eq. 8); per-sample (loss, PA, PC)
           recorded from the training forward pass ("lagging loss")
  D        forward-only refresh of the hidden set at epoch end

This module is model-agnostic: the trainer supplies
  train_step(batch_indices)  -> (per-sample loss, pa, pc) and
  eval_forward(batch_indices) -> (loss, pa, pc)
while this class owns the SampleState and the epoch plan.

Device residency: the whole epoch plan — selection, move-back and the
visible-index permutation — is ONE jitted step (``_plan_step``) driven by a
checkpointable jax PRNG key, and per-batch observation is fused into the
trainer's jitted train step (``KakurenboStrategy.fused_observe``).
``SampleState`` therefore crosses the host boundary exactly once per epoch:
the ``jax.device_get`` that materialises the EpochPlan's index lists.

Mesh sharding: given a ``ParallelCtx`` with a ``("data",)`` mesh
(``TrainConfig.mesh_shape``), ``SampleState`` is row-sharded over the data
axis and the plan step becomes a *cross-shard* plan: the histogram selection
methods run under shard_map — each shard histograms its own rows, the
histograms are psum'd (O(bins) communication) and every shard derives the
same global threshold — while ``"sort"`` falls back to a global GSPMD
argsort (the O(N) gather the paper's own method costs).  The epoch shuffle
uses the replicated device PRNG key, so the permutation — and with it the
hide/move-back masks and the batch order — is bit-identical across mesh
sizes (enforced by ``tests/test_mesh_trainer.py``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import planops
from repro.core import selection as sel
from repro.core.schedule import FractionSchedule, kakurenbo_lr
from repro.core.state import SampleState, init_sample_state, scatter_observations, with_hidden
from repro.core.strategy import EpochPlan, SampleStrategy, register_strategy
from repro.dist.sharding import ParallelCtx, shard_map_compat


@dataclasses.dataclass
class KakurenboConfig:
    max_fraction: float = 0.3
    fraction_alphas: tuple[float, ...] = (1.0, 0.8, 0.6, 0.4)
    fraction_milestones: tuple[int, ...] = (0, 30, 60, 80)
    tau: float = 0.7
    # "sort" (paper) | "histogram" (optimized) | "histogram_pallas" (kernel)
    selection: str = "sort"
    drop_top_fraction: float = 0.0  # DropTop (App. D)
    adjust_lr: bool = True          # LR component (Eq. 8)
    moveback: bool = True           # MB component
    reduce_fraction: bool = True    # RF component
    # Component toggles above express Table 6's v1000..v1111 ablations.


@functools.partial(
    jax.jit,
    static_argnames=("method", "tau", "drop_top", "moveback", "adjust_lr",
                     "mesh"))
def _plan_step(state: SampleState, key: jax.Array, f_max: jax.Array, *,
               method: str, tau: float, drop_top: float, moveback: bool,
               adjust_lr: bool, mesh=None):
    """The entire epoch plan as one device-resident step.

    Selection + move-back + the visible/hidden split + the epoch shuffle all
    happen on device; returns (hidden mask, moved-back mask, permuted index
    order with the visible set first, hidden count, F*, Eq. 8 LR factor).

    With ``mesh`` (a ``("data",)`` mesh; ``state`` row-sharded over it) this
    is a *cross-shard* plan: the histogram methods run their selection under
    shard_map — per-shard histograms psum'd into a globally consistent
    threshold, O(bins) communication — while ``"sort"`` runs as a global
    GSPMD argsort (O(N) gather, the paper method's own cost).  The shuffle
    key is replicated, so masks and batch order are identical for every mesh
    size, ``(1,)`` included.
    """
    if mesh is not None and method in ("histogram", "histogram_pallas"):
        def local_select(st, fm):
            return sel.select_hidden_histogram(
                st, fm, tau=tau, axis_names=("data",),
                drop_top_fraction=drop_top, moveback=moveback,
                use_kernel=(method == "histogram_pallas"))

        hidden = shard_map_compat(
            local_select, mesh=mesh, in_specs=(P("data"), P()),
            out_specs=P("data"))(state, f_max)
    else:
        hidden = sel.select_hidden(state, f_max, method=method, tau=tau,
                                   drop_top_fraction=drop_top,
                                   moveback=moveback)
    # Move-back set (Sec. 3.1): hidden last epoch, visible again this epoch.
    moved_back = state.hidden & ~hidden
    n = state.num_samples
    perm = jax.random.permutation(key, n)
    # Stable-sort the random permutation by hiddenness: visible indices come
    # first in uniformly-shuffled order (the epoch's batch order), hidden
    # indices follow — one fixed-shape array instead of two ragged ones.
    order = perm[jnp.argsort(hidden[perm], stable=True)]
    num_hidden = jnp.sum(hidden).astype(jnp.int32)
    f_star = num_hidden.astype(jnp.float32) / n
    if adjust_lr:
        lr_scale = kakurenbo_lr(jnp.float32(1.0), f_star)
    else:
        lr_scale = jnp.float32(1.0)
    return hidden, moved_back, order, num_hidden, f_star, lr_scale


class KakurenboSampler:
    """Owns SampleState + epoch planning. Host-side glue; math is jitted."""

    def __init__(self, num_samples: int, config: KakurenboConfig | None = None,
                 seed: int = 0, ctx: ParallelCtx | None = None):
        self.config = config or KakurenboConfig()
        self.ctx = ctx or ParallelCtx()
        self.ctx.check_rows(num_samples)
        # Row-sharded over the data axes under a mesh; plain device arrays
        # otherwise (shard_rows is the identity with no mesh).
        self.state: SampleState = self.ctx.shard_rows(
            init_sample_state(num_samples))
        # The unified planops seeding convention (one key per strategy name).
        self._key = self.ctx.replicate(planops.strategy_key(seed, "kakurenbo"))
        # Host round trips involving SampleState: host-dispatched observe
        # scatters + per-epoch plan materialisations. The fused trainer path
        # keeps this at 1/epoch; the legacy path pays 1/batch on top.
        self.host_round_trips = 0
        c = self.config
        self._fraction_schedule = FractionSchedule(
            max_fraction=c.max_fraction,
            alphas=c.fraction_alphas if c.reduce_fraction else (1.0,) * len(c.fraction_alphas),
            milestones=c.fraction_milestones,
        )
        self._observe = jax.jit(scatter_observations)

    # -- epoch boundary ------------------------------------------------------

    def begin_epoch(self, epoch: int) -> EpochPlan:
        c = self.config
        f_max = float(self._fraction_schedule(epoch))
        self._key, sub = jax.random.split(self._key)
        hidden, moved_back, order, num_hidden, f_star, lr_scale = _plan_step(
            self.state, sub, jnp.float32(f_max), method=c.selection,
            tau=c.tau, drop_top=c.drop_top_fraction, moveback=c.moveback,
            adjust_lr=c.adjust_lr, mesh=self.ctx.mesh)
        self.state = with_hidden(self.state, hidden)
        # The single host sync of the epoch: materialise the plan (one
        # device_get for the order, the move-back mask and the scalars).
        order_np, mb_np, nh, f_star, lr_scale = jax.device_get(
            (order, moved_back, num_hidden, f_star, lr_scale))
        self.host_round_trips += 1
        n = self.state.num_samples
        nh = int(nh)
        return EpochPlan(
            epoch=epoch,
            visible_indices=order_np[: n - nh],
            hidden_indices=np.sort(order_np[n - nh:]),
            max_fraction=f_max,
            hidden_fraction=float(f_star),
            lr_scale=float(lr_scale),
            needs_refresh=nh > 0,
            host_syncs=1,
            moveback_indices=np.flatnonzero(mb_np),
        )

    # -- per-batch bookkeeping ----------------------------------------------

    def observe(self, indices: np.ndarray | jax.Array, loss: jax.Array,
                pa: jax.Array, pc: jax.Array, epoch: int) -> None:
        """Record lagging loss/PA/PC from a training or refresh batch.

        Host-dispatched path; the fused trainer performs this scatter inside
        its jitted train step instead (see ``KakurenboStrategy.fused_observe``).
        """
        self.host_round_trips += 1
        self.state = self._observe(self.state, jnp.asarray(indices), loss, pa,
                                   pc, epoch)

    # -- epoch end: refresh hidden list (step D) ------------------------------

    def refresh_hidden(
        self,
        plan: EpochPlan,
        eval_forward: Callable[[np.ndarray], tuple[jax.Array, jax.Array, jax.Array]],
        batch_size: int,
    ) -> int:
        """Forward-only pass over the hidden list (paper step D.1).

        Returns the number of refreshed samples — padding excluded, so the
        count is exactly the useful forward-only extra work.
        """
        hidden = plan.hidden_indices
        for start in range(0, len(hidden), batch_size):
            idx = hidden[start : start + batch_size]
            # range() guarantees idx is non-empty; the trailing batch is
            # padded (repeating its last index) to keep a single jit
            # signature, and the padded tail is sliced off before observe.
            if len(idx) < batch_size:
                pad = np.full(batch_size - len(idx), idx[-1])
                loss, pa, pc = eval_forward(np.concatenate([idx, pad]))
                loss, pa, pc = loss[: len(idx)], pa[: len(idx)], pc[: len(idx)]
            else:
                loss, pa, pc = eval_forward(idx)
            self.observe(idx, loss, pa, pc, plan.epoch)
        return int(len(hidden))

    def batches(self, plan: EpochPlan, batch_size: int) -> Iterator[np.ndarray]:
        """Uniform w/o-replacement batches over the visible set (step C).

        Drops the trailing partial batch, like the paper's DDP loaders.
        """
        v = plan.visible_indices
        for start in range(0, len(v) - batch_size + 1, batch_size):
            yield v[start : start + batch_size]

    # -- checkpointable device RNG -------------------------------------------

    def key_data(self) -> jax.Array:
        """Serializable uint32 view of the epoch-shuffle PRNG key."""
        return planops.key_data(self._key)

    def load_key_data(self, data) -> None:
        self._key = self.ctx.replicate(planops.load_key(data))


@register_strategy("kakurenbo")
class KakurenboStrategy(SampleStrategy):
    """The paper's method behind the unified strategy protocol."""

    config_cls, config_field = KakurenboConfig, "kakurenbo"
    fused_observe = staticmethod(scatter_observations)

    def __init__(self, num_samples: int, config: KakurenboConfig | None = None,
                 seed: int = 0, ctx: ParallelCtx | None = None):
        super().__init__(num_samples, config, seed)
        self._inner = KakurenboSampler(num_samples, config, seed, ctx=ctx)

    @property
    def state(self) -> SampleState:
        return self._inner.state

    @state.setter
    def state(self, value: SampleState) -> None:
        self._inner.state = value

    @property
    def host_round_trips(self) -> int:
        return self._inner.host_round_trips

    def get_device_state(self) -> SampleState:
        return self._inner.state

    def set_device_state(self, state: SampleState) -> None:
        self._inner.state = state

    def plan(self, epoch: int) -> EpochPlan:
        return self._inner.begin_epoch(epoch)

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        self._inner.observe(indices, loss, pa, pc, epoch)

    def on_epoch_end(self, plan: EpochPlan, eval_forward, batch_size: int) -> int:
        return self._inner.refresh_hidden(plan, eval_forward, batch_size)

    def state_dict(self) -> dict:
        return {"arrays": {"state": self._inner.state,
                           "rng_key": self._inner.key_data()},
                "host": {"rng_impl": "threefry2x32"}}

    def load_state_dict(self, state: dict) -> None:
        self._inner.state = self._inner.ctx.shard_rows(
            jax.tree.map(jnp.asarray, state["arrays"]["state"]))
        self._inner.load_key_data(state["arrays"]["rng_key"])
