"""KAKURENBO epoch orchestration (paper Fig. 1).

Per epoch e:
  B.1/B.2  rank samples by lagging loss, hide lowest-loss fraction <= F_e
  B.3      move back candidates not (correct & PC >= tau) last epoch
  C        train on the visible set with uniform w/o-replacement sampling;
           LR multiplied by 1/(1-F*_e) (Eq. 8); per-sample (loss, PA, PC)
           recorded from the training forward pass ("lagging loss")
  D        forward-only refresh of the hidden set at epoch end

This module is model-agnostic: the trainer supplies
  train_step(batch_indices)  -> (per-sample loss, pa, pc) and
  eval_forward(batch_indices) -> (loss, pa, pc)
while this class owns the SampleState and the epoch plan.

Device residency: the whole epoch plan — selection, move-back and the
visible-index permutation — is ONE jitted step (``_plan_step``) driven by a
checkpointable jax PRNG key, and per-batch observation is fused into the
trainer's jitted train step (``KakurenboStrategy.fused_observe``).
``SampleState`` therefore crosses the host boundary exactly once per epoch:
the ``jax.device_get`` that materialises the EpochPlan's index lists.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import selection as sel
from repro.core.schedule import FractionSchedule, kakurenbo_lr
from repro.core.state import SampleState, init_sample_state, scatter_observations, with_hidden
from repro.core.strategy import EpochPlan, SampleStrategy, register_strategy


@dataclasses.dataclass
class KakurenboConfig:
    max_fraction: float = 0.3
    fraction_alphas: tuple[float, ...] = (1.0, 0.8, 0.6, 0.4)
    fraction_milestones: tuple[int, ...] = (0, 30, 60, 80)
    tau: float = 0.7
    # "sort" (paper) | "histogram" (optimized) | "histogram_pallas" (kernel)
    selection: str = "sort"
    drop_top_fraction: float = 0.0  # DropTop (App. D)
    adjust_lr: bool = True          # LR component (Eq. 8)
    moveback: bool = True           # MB component
    reduce_fraction: bool = True    # RF component
    # Component toggles above express Table 6's v1000..v1111 ablations.


@functools.partial(
    jax.jit,
    static_argnames=("method", "tau", "drop_top", "moveback", "adjust_lr"))
def _plan_step(state: SampleState, key: jax.Array, f_max: jax.Array, *,
               method: str, tau: float, drop_top: float, moveback: bool,
               adjust_lr: bool):
    """The entire epoch plan as one device-resident step.

    Selection + move-back + the visible/hidden split + the epoch shuffle all
    happen on device; returns (hidden mask, permuted index order with the
    visible set first, hidden count, F*, Eq. 8 LR factor).
    """
    hidden = sel.select_hidden(state, f_max, method=method, tau=tau,
                               drop_top_fraction=drop_top, moveback=moveback)
    n = state.num_samples
    perm = jax.random.permutation(key, n)
    # Stable-sort the random permutation by hiddenness: visible indices come
    # first in uniformly-shuffled order (the epoch's batch order), hidden
    # indices follow — one fixed-shape array instead of two ragged ones.
    order = perm[jnp.argsort(hidden[perm], stable=True)]
    num_hidden = jnp.sum(hidden).astype(jnp.int32)
    f_star = num_hidden.astype(jnp.float32) / n
    if adjust_lr:
        lr_scale = kakurenbo_lr(jnp.float32(1.0), f_star)
    else:
        lr_scale = jnp.float32(1.0)
    return hidden, order, num_hidden, f_star, lr_scale


class KakurenboSampler:
    """Owns SampleState + epoch planning. Host-side glue; math is jitted."""

    def __init__(self, num_samples: int, config: KakurenboConfig | None = None,
                 seed: int = 0):
        self.config = config or KakurenboConfig()
        self.state: SampleState = init_sample_state(num_samples)
        self._key = jax.random.key(seed)
        # Host round trips involving SampleState: host-dispatched observe
        # scatters + per-epoch plan materialisations. The fused trainer path
        # keeps this at 1/epoch; the legacy path pays 1/batch on top.
        self.host_round_trips = 0
        c = self.config
        self._fraction_schedule = FractionSchedule(
            max_fraction=c.max_fraction,
            alphas=c.fraction_alphas if c.reduce_fraction else (1.0,) * len(c.fraction_alphas),
            milestones=c.fraction_milestones,
        )
        self._observe = jax.jit(scatter_observations)

    # -- epoch boundary ------------------------------------------------------

    def begin_epoch(self, epoch: int) -> EpochPlan:
        c = self.config
        f_max = float(self._fraction_schedule(epoch))
        self._key, sub = jax.random.split(self._key)
        hidden, order, num_hidden, f_star, lr_scale = _plan_step(
            self.state, sub, jnp.float32(f_max), method=c.selection,
            tau=c.tau, drop_top=c.drop_top_fraction, moveback=c.moveback,
            adjust_lr=c.adjust_lr)
        self.state = with_hidden(self.state, hidden)
        # The single host sync of the epoch: materialise the plan.
        order_np, nh, f_star, lr_scale = jax.device_get(
            (order, num_hidden, f_star, lr_scale))
        self.host_round_trips += 1
        n = self.state.num_samples
        nh = int(nh)
        return EpochPlan(
            epoch=epoch,
            visible_indices=order_np[: n - nh],
            hidden_indices=np.sort(order_np[n - nh:]),
            max_fraction=f_max,
            hidden_fraction=float(f_star),
            lr_scale=float(lr_scale),
            needs_refresh=nh > 0,
            host_syncs=1,
        )

    # -- per-batch bookkeeping ----------------------------------------------

    def observe(self, indices: np.ndarray | jax.Array, loss: jax.Array,
                pa: jax.Array, pc: jax.Array, epoch: int) -> None:
        """Record lagging loss/PA/PC from a training or refresh batch.

        Host-dispatched path; the fused trainer performs this scatter inside
        its jitted train step instead (see ``KakurenboStrategy.fused_observe``).
        """
        self.host_round_trips += 1
        self.state = self._observe(self.state, jnp.asarray(indices), loss, pa,
                                   pc, epoch)

    # -- epoch end: refresh hidden list (step D) ------------------------------

    def refresh_hidden(
        self,
        plan: EpochPlan,
        eval_forward: Callable[[np.ndarray], tuple[jax.Array, jax.Array, jax.Array]],
        batch_size: int,
    ) -> int:
        """Forward-only pass over the hidden list (paper step D.1).

        Returns the number of refreshed samples — padding excluded, so the
        count is exactly the useful forward-only extra work.
        """
        hidden = plan.hidden_indices
        for start in range(0, len(hidden), batch_size):
            idx = hidden[start : start + batch_size]
            # range() guarantees idx is non-empty; the trailing batch is
            # padded (repeating its last index) to keep a single jit
            # signature, and the padded tail is sliced off before observe.
            if len(idx) < batch_size:
                pad = np.full(batch_size - len(idx), idx[-1])
                loss, pa, pc = eval_forward(np.concatenate([idx, pad]))
                loss, pa, pc = loss[: len(idx)], pa[: len(idx)], pc[: len(idx)]
            else:
                loss, pa, pc = eval_forward(idx)
            self.observe(idx, loss, pa, pc, plan.epoch)
        return int(len(hidden))

    def batches(self, plan: EpochPlan, batch_size: int) -> Iterator[np.ndarray]:
        """Uniform w/o-replacement batches over the visible set (step C).

        Drops the trailing partial batch, like the paper's DDP loaders.
        """
        v = plan.visible_indices
        for start in range(0, len(v) - batch_size + 1, batch_size):
            yield v[start : start + batch_size]

    # -- checkpointable device RNG -------------------------------------------

    def key_data(self) -> jax.Array:
        """Serializable uint32 view of the epoch-shuffle PRNG key."""
        return jax.random.key_data(self._key)

    def load_key_data(self, data) -> None:
        self._key = jax.random.wrap_key_data(
            jnp.asarray(data, jnp.uint32), impl="threefry2x32")


@register_strategy("kakurenbo")
class KakurenboStrategy(SampleStrategy):
    """The paper's method behind the unified strategy protocol."""

    config_cls, config_field = KakurenboConfig, "kakurenbo"
    fused_observe = staticmethod(scatter_observations)

    def __init__(self, num_samples: int, config: KakurenboConfig | None = None,
                 seed: int = 0):
        super().__init__(num_samples, config, seed)
        self._inner = KakurenboSampler(num_samples, config, seed)

    @property
    def state(self) -> SampleState:
        return self._inner.state

    @state.setter
    def state(self, value: SampleState) -> None:
        self._inner.state = value

    @property
    def host_round_trips(self) -> int:
        return self._inner.host_round_trips

    def get_device_state(self) -> SampleState:
        return self._inner.state

    def set_device_state(self, state: SampleState) -> None:
        self._inner.state = state

    def plan(self, epoch: int) -> EpochPlan:
        return self._inner.begin_epoch(epoch)

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        self._inner.observe(indices, loss, pa, pc, epoch)

    def on_epoch_end(self, plan: EpochPlan, eval_forward, batch_size: int) -> int:
        return self._inner.refresh_hidden(plan, eval_forward, batch_size)

    def state_dict(self) -> dict:
        return {"arrays": {"state": self._inner.state,
                           "rng_key": self._inner.key_data()},
                "host": {"rng_impl": "threefry2x32"}}

    def load_state_dict(self, state: dict) -> None:
        self._inner.state = jax.tree.map(jnp.asarray, state["arrays"]["state"])
        self._inner.load_key_data(state["arrays"]["rng_key"])
