"""KAKURENBO epoch orchestration (paper Fig. 1).

Per epoch e:
  B.1/B.2  rank samples by lagging loss, hide lowest-loss fraction <= F_e
  B.3      move back candidates not (correct & PC >= tau) last epoch
  C        train on the visible set with uniform w/o-replacement sampling;
           LR multiplied by 1/(1-F*_e) (Eq. 8); per-sample (loss, PA, PC)
           recorded from the training forward pass ("lagging loss")
  D        forward-only refresh of the hidden set at epoch end

This module is model-agnostic: the trainer supplies
  train_step(batch_indices)  -> (per-sample loss, pa, pc) and
  eval_forward(batch_indices) -> (loss, pa, pc)
while this class owns the SampleState and the epoch plan.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import selection as sel
from repro.core.schedule import FractionSchedule, kakurenbo_lr
from repro.core.state import SampleState, init_sample_state, scatter_observations, with_hidden
from repro.core.strategy import (
    EpochPlan, SampleStrategy, register_strategy, rng_state, set_rng_state,
)


@dataclasses.dataclass
class KakurenboConfig:
    max_fraction: float = 0.3
    fraction_alphas: tuple[float, ...] = (1.0, 0.8, 0.6, 0.4)
    fraction_milestones: tuple[int, ...] = (0, 30, 60, 80)
    tau: float = 0.7
    selection: str = "sort"        # "sort" (paper) | "histogram" (optimized)
    drop_top_fraction: float = 0.0  # DropTop (App. D)
    adjust_lr: bool = True          # LR component (Eq. 8)
    moveback: bool = True           # MB component
    reduce_fraction: bool = True    # RF component
    # Component toggles above express Table 6's v1000..v1111 ablations.


class KakurenboSampler:
    """Owns SampleState + epoch planning. Host-side glue; math is jitted."""

    def __init__(self, num_samples: int, config: KakurenboConfig | None = None,
                 seed: int = 0):
        self.config = config or KakurenboConfig()
        self.state: SampleState = init_sample_state(num_samples)
        self._rng = np.random.default_rng(seed)
        c = self.config
        self._fraction_schedule = FractionSchedule(
            max_fraction=c.max_fraction,
            alphas=c.fraction_alphas if c.reduce_fraction else (1.0,) * len(c.fraction_alphas),
            milestones=c.fraction_milestones,
        )
        self._observe = jax.jit(scatter_observations)

    # -- epoch boundary ------------------------------------------------------

    def begin_epoch(self, epoch: int) -> EpochPlan:
        c = self.config
        f_max = float(self._fraction_schedule(epoch))
        if c.moveback:
            hidden = sel.select_hidden(
                self.state, f_max, method=c.selection, tau=c.tau,
                drop_top_fraction=c.drop_top_fraction)
        else:
            hidden = _select_no_moveback(self.state, f_max, c.selection,
                                         c.drop_top_fraction)
        self.state = with_hidden(self.state, hidden)
        hidden_np = np.asarray(hidden)
        all_idx = np.arange(self.state.num_samples)
        visible = all_idx[~hidden_np]
        self._rng.shuffle(visible)
        f_star = float(hidden_np.mean())
        lr_scale = float(kakurenbo_lr(jnp.float32(1.0), f_star)) if c.adjust_lr else 1.0
        return EpochPlan(
            epoch=epoch,
            visible_indices=visible,
            hidden_indices=all_idx[hidden_np],
            max_fraction=f_max,
            hidden_fraction=f_star,
            lr_scale=lr_scale,
            needs_refresh=bool(hidden_np.any()),
        )

    # -- per-batch bookkeeping ----------------------------------------------

    def observe(self, indices: np.ndarray | jax.Array, loss: jax.Array,
                pa: jax.Array, pc: jax.Array, epoch: int) -> None:
        """Record lagging loss/PA/PC from a training or refresh batch."""
        self.state = self._observe(self.state, jnp.asarray(indices), loss, pa,
                                   pc, epoch)

    # -- epoch end: refresh hidden list (step D) ------------------------------

    def refresh_hidden(
        self,
        plan: EpochPlan,
        eval_forward: Callable[[np.ndarray], tuple[jax.Array, jax.Array, jax.Array]],
        batch_size: int,
    ) -> int:
        """Forward-only pass over the hidden list (paper step D.1).

        Returns the number of refreshed samples — padding excluded, so the
        count is exactly the useful forward-only extra work.
        """
        hidden = plan.hidden_indices
        for start in range(0, len(hidden), batch_size):
            idx = hidden[start : start + batch_size]
            # range() guarantees idx is non-empty; the trailing batch is
            # padded (repeating its last index) to keep a single jit
            # signature, and the padded tail is sliced off before observe.
            if len(idx) < batch_size:
                pad = np.full(batch_size - len(idx), idx[-1])
                loss, pa, pc = eval_forward(np.concatenate([idx, pad]))
                loss, pa, pc = loss[: len(idx)], pa[: len(idx)], pc[: len(idx)]
            else:
                loss, pa, pc = eval_forward(idx)
            self.observe(idx, loss, pa, pc, plan.epoch)
        return int(len(hidden))

    def batches(self, plan: EpochPlan, batch_size: int) -> Iterator[np.ndarray]:
        """Uniform w/o-replacement batches over the visible set (step C).

        Drops the trailing partial batch, like the paper's DDP loaders.
        """
        v = plan.visible_indices
        for start in range(0, len(v) - batch_size + 1, batch_size):
            yield v[start : start + batch_size]


@register_strategy("kakurenbo")
class KakurenboStrategy(SampleStrategy):
    """The paper's method behind the unified strategy protocol."""

    config_cls, config_field = KakurenboConfig, "kakurenbo"

    def __init__(self, num_samples: int, config: KakurenboConfig | None = None,
                 seed: int = 0):
        super().__init__(num_samples, config, seed)
        self._inner = KakurenboSampler(num_samples, config, seed)

    @property
    def state(self) -> SampleState:
        return self._inner.state

    @state.setter
    def state(self, value: SampleState) -> None:
        self._inner.state = value

    def plan(self, epoch: int) -> EpochPlan:
        return self._inner.begin_epoch(epoch)

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        self._inner.observe(indices, loss, pa, pc, epoch)

    def on_epoch_end(self, plan: EpochPlan, eval_forward, batch_size: int) -> int:
        return self._inner.refresh_hidden(plan, eval_forward, batch_size)

    def state_dict(self) -> dict:
        return {"arrays": {"state": self._inner.state},
                "host": {"rng": rng_state(self._inner._rng)}}

    def load_state_dict(self, state: dict) -> None:
        self._inner.state = jax.tree.map(jnp.asarray, state["arrays"]["state"])
        set_rng_state(self._inner._rng, state["host"]["rng"])


def _select_no_moveback(state: SampleState, f_max: float, method: str,
                        drop_top: float) -> jax.Array:
    """HE without MB: hide the lowest-loss candidates unconditionally."""
    n = state.num_samples
    num_hide = int(np.floor(f_max * n))
    order = jnp.argsort(state.loss)
    rank = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    hidden = (rank < num_hide) & (state.seen >= 0)
    if drop_top > 0:
        num_top = int(np.floor(drop_top * n))
        hidden = hidden | ((rank >= n - num_top) & (state.seen >= 0))
    return hidden
