"""Selective-Backprop baseline [17] (paper Sec. 4, "SB").

Forward the whole batch, then backprop only samples selected with probability
P(select | loss) = percentile(loss)^beta; beta=1 keeps ~50% on average (the
paper's setting).  The loss percentile is estimated against a running history
of recent batch losses, as in the reference implementation.

Device residency: the forward-then-mask flow is the protocol's *in-step*
``fused_select`` hook — the trainer computes a forward-only loss inside its
jitted train step, ``select_step`` turns it into per-sample backward weights
(0 = dropped, survivors rescaled so the kept mean loss is unbiased) and
updates the device-resident history ring buffer + PRNG key.  Nothing crosses
the host mid-epoch, so SB scans (``supports_scan``) like every other
strategy; on real hardware the saved work comes from re-batching the
selected samples, and the roofline accounts the reduced backward FLOPs
analytically (benchmarks/fig2_speedup.py).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import planops
from repro.core.strategy import EpochPlan, SampleStrategy, register_strategy
from repro.dist.sharding import ParallelCtx


@dataclasses.dataclass
class SBConfig:
    beta: float = 1.0
    history: int = 4096   # sliding window of recent losses for percentiles
    floor: float = 0.05   # minimum selection probability (avoid starving)
    bootstrap: int = 32   # train on everything until this many losses seen


def init_select_state(config: SBConfig, key: jax.Array) -> dict:
    """Device-resident selection state: history ring buffer + PRNG key.

    Unwritten slots are +inf so they sort past every real loss and never
    perturb the percentile estimate.
    """
    h = config.history
    return {"hist": jnp.full((h,), jnp.inf, jnp.float32),
            "count": jnp.zeros((), jnp.int32),
            "ptr": jnp.zeros((), jnp.int32),
            "key": key}


def select_step(state: dict, loss: jax.Array, *, beta: float, floor: float,
                bootstrap: int) -> tuple[jax.Array, dict]:
    """Pure in-step select: ``(state, (B,) loss) -> (weights, state)``.

    The percentile of each loss within the history window drives a Bernoulli
    keep draw; kept samples are rescaled by B/kept so the batch loss stays
    unbiased.  During bootstrap (fewer than ``bootstrap`` observed losses)
    everything trains.  The update appends the batch to the ring buffer and
    splits the carried key — fully deterministic given the state, which is
    what makes the flow scan- and checkpoint-safe.
    """
    h = state["hist"].shape[0]
    b = loss.shape[0]
    loss = loss.astype(jnp.float32)
    key, sub = jax.random.split(state["key"])
    filled = jnp.minimum(state["count"], h)
    sorted_hist = jnp.sort(state["hist"])       # +inf (unwritten) sorts last
    pct = (jnp.searchsorted(sorted_hist, loss, side="left")
           / jnp.maximum(filled, 1))
    prob = jnp.where(state["count"] < bootstrap, 1.0,
                     jnp.maximum(pct ** beta, floor))
    keep = (jax.random.uniform(sub, (b,)) < prob).astype(jnp.float32)
    weights = keep * (b / jnp.maximum(keep.sum(), 1.0))
    pos = (state["ptr"] + jnp.arange(b, dtype=jnp.int32)) % h
    new_state = {"hist": state["hist"].at[pos].set(loss),
                 "count": jnp.minimum(state["count"] + b, jnp.int32(1 << 30)),
                 "ptr": (state["ptr"] + b) % h,
                 "key": key}
    return weights, new_state


class SelectiveBackprop:
    """Host-API wrapper over the device select core (direct/low-level use)."""

    def __init__(self, config: SBConfig | None = None, seed: int = 0):
        self.config = config or SBConfig()
        c = self.config
        self._state = init_select_state(c, planops.strategy_key(seed, "sb"))
        self._select = jax.jit(functools.partial(
            select_step, beta=c.beta, floor=c.floor, bootstrap=c.bootstrap))

    def select(self, batch_loss: np.ndarray) -> np.ndarray:
        """Return f32 0/1 backward mask for this batch and update history."""
        w, self._state = self._select(self._state,
                                      jnp.asarray(batch_loss, jnp.float32))
        return (np.asarray(w) > 0).astype(np.float32)


@register_strategy("sb")
class SBStrategy(SampleStrategy):
    """Forward-then-mask selection as the in-step ``fused_select`` hook: the
    trainer fuses the forward-only loss and the masked backward into one
    jitted step — no strategy-specific branch in the training loop, and the
    whole epoch scans."""

    config_cls, config_field = SBConfig, "sb"

    def __init__(self, num_samples: int, config: SBConfig | None = None,
                 seed: int = 0, ctx: ParallelCtx | None = None):
        super().__init__(num_samples, config or SBConfig(), seed)
        self.ctx = ctx or ParallelCtx()
        c = self.config
        self._sel = self.ctx.replicate(
            init_select_state(c, planops.strategy_key(seed, "sb")))
        self._key = self.ctx.replicate(planops.strategy_key(seed, "sb-plan"))
        self.fused_select = functools.partial(
            select_step, beta=c.beta, floor=c.floor, bootstrap=c.bootstrap)

    def plan(self, epoch: int) -> EpochPlan:
        self._key, sub = jax.random.split(self._key)
        order = planops.device_permutation(sub, self.num_samples)
        return EpochPlan(epoch=epoch,
                         visible_indices=np.asarray(jax.device_get(order)),
                         host_syncs=1)

    def get_device_state(self) -> dict:
        return self._sel

    def set_device_state(self, state: dict) -> None:
        self._sel = state

    def state_dict(self) -> dict:
        sel = self._sel
        return {"arrays": {"hist": sel["hist"], "count": sel["count"],
                           "ptr": sel["ptr"],
                           "sel_key": planops.key_data(sel["key"]),
                           "rng_key": planops.key_data(self._key)},
                "host": {"rng_impl": planops.KEY_IMPL}}

    def load_state_dict(self, state: dict) -> None:
        a = state["arrays"]
        host = state.get("host") or {}
        h = self.config.history
        if "rng_key" in a:
            self._key = self.ctx.replicate(planops.load_key(a["rng_key"]))
            sel_key = planops.load_key(a["sel_key"])
            hist = jnp.asarray(a["hist"], jnp.float32)
            count = jnp.asarray(a["count"], jnp.int32)
            ptr = jnp.asarray(a["ptr"], jnp.int32)
        else:
            # Legacy (pre-PlanOps) format: a growing host history plus two
            # numpy RNG states.  Write the stored losses into the ring
            # buffer and derive device keys from the generator states — the
            # resumed run is deterministic but continues on the device RNG
            # stream (see planops.migrate_legacy_rng).
            old = np.asarray(a["hist"], np.float32)[-h:]
            buf = np.full((h,), np.inf, np.float32)
            buf[: len(old)] = old
            hist = jnp.asarray(buf)
            count = jnp.int32(len(old))
            ptr = jnp.int32(len(old) % h)
            self._key = self.ctx.replicate(planops.migrate_legacy_rng(
                host.get("rng", {}), self.seed, "sb-plan"))
            sel_key = planops.migrate_legacy_rng(
                host.get("inner_rng", {}), self.seed, "sb")
        self._sel = self.ctx.replicate(
            {"hist": hist, "count": count, "ptr": ptr, "key": sel_key})
