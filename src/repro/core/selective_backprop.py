"""Selective-Backprop baseline [17] (paper Sec. 4, "SB").

Forward the whole batch, then backprop only samples selected with probability
P(select | loss) = percentile(loss)^beta; beta=1 keeps ~50% on average (the
paper's setting).  Implemented as a per-batch 0/1 weight vector applied to
the loss, so the backward pass is *masked* — on real hardware the saved work
comes from re-batching the selected samples; on the roofline we account for
the reduced backward FLOPs analytically (benchmarks/fig2_speedup.py).

The loss percentile is estimated against a running history of recent batch
losses, as in the reference implementation.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.strategy import (
    EpochPlan, SampleStrategy, register_strategy, rng_state, set_rng_state,
)


@dataclasses.dataclass
class SBConfig:
    beta: float = 1.0
    history: int = 4096   # sliding window of recent losses for percentiles
    floor: float = 0.05   # minimum selection probability (avoid starving)


class SelectiveBackprop:
    def __init__(self, config: SBConfig | None = None, seed: int = 0):
        self.config = config or SBConfig()
        self._rng = np.random.default_rng(seed)
        self._hist = np.zeros(0, np.float32)

    def select(self, batch_loss: np.ndarray) -> np.ndarray:
        """Return f32 0/1 backward mask for this batch and update history."""
        c = self.config
        if len(self._hist) < 32:  # bootstrap: train on everything
            prob = np.ones_like(batch_loss, np.float64)
        else:
            # percentile of each loss within the history window
            pct = np.searchsorted(np.sort(self._hist), batch_loss) / len(self._hist)
            prob = np.maximum(pct ** c.beta, c.floor)
        keep = (self._rng.random(len(batch_loss)) < prob).astype(np.float32)
        self._hist = np.concatenate([self._hist, batch_loss.astype(np.float32)])[-c.history:]
        return keep


@register_strategy("sb")
class SBStrategy(SampleStrategy):
    """Forward-then-mask selection as a protocol-level ``select_batch`` hook:
    the trainer sees ``needs_batch_loss`` and supplies the forward-only
    losses — no strategy-specific branch in the training loop."""

    config_cls, config_field = SBConfig, "sb"
    needs_batch_loss = True

    def __init__(self, num_samples: int, config: SBConfig | None = None,
                 seed: int = 0):
        super().__init__(num_samples, config, seed)
        self._inner = SelectiveBackprop(config, seed)
        self._rng = np.random.default_rng(seed + 1)

    def plan(self, epoch: int) -> EpochPlan:
        idx = np.arange(self.num_samples)
        self._rng.shuffle(idx)
        return EpochPlan(epoch=epoch, visible_indices=idx)

    def select_batch(self, indices: np.ndarray,
                     loss: np.ndarray) -> np.ndarray:
        """0/1 keep mask rescaled so the kept samples' mean loss is unbiased."""
        keep = self._inner.select(np.asarray(loss))
        return keep * (len(keep) / max(keep.sum(), 1.0))

    def state_dict(self) -> dict:
        return {"arrays": {"hist": self._inner._hist},
                "host": {"rng": rng_state(self._rng),
                         "inner_rng": rng_state(self._inner._rng)}}

    def load_state_dict(self, state: dict) -> None:
        self._inner._hist = np.asarray(state["arrays"]["hist"], np.float32)
        set_rng_state(self._rng, state["host"]["rng"])
        set_rng_state(self._inner._rng, state["host"]["inner_rng"])
