"""Online FORGET baseline (paper Sec. 4; Toneva et al. [13]).

Train ``warmup_epochs`` (paper: 20) on the full dataset while counting
*forgetting events* (correct -> incorrect transitions, maintained for free in
SampleState).  Then prune the fraction F of the *least-forgettable* samples
(fewest forgetting events, ties broken by never-misclassified first) and
restart training from epoch 0 on the pruned set.  Total reported cost must
include the warmup epochs (paper Sec. 4.2).

Planning is device-resident (``core/planops.py``): the prune set is the
stable fewest-events-first rank (``planops.topk_hide``) over the device
forget-event counts, the epoch shuffle is ``planops.masked_order`` driven by
a checkpointable PRNG key, and the epoch's index list crosses to the host in
a single ``jax.device_get``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import planops
from repro.core.state import SampleState, init_sample_state, scatter_observations
from repro.core.strategy import EpochPlan, SampleStrategy, register_strategy
from repro.dist.sharding import ParallelCtx


@dataclasses.dataclass
class ForgetConfig:
    fraction: float = 0.3
    warmup_epochs: int = 20


@functools.partial(jax.jit, static_argnames=("mesh",))
def _prune_step(state: SampleState, k: jax.Array, *, mesh=None) -> jax.Array:
    """Mask of the k least-forgettable samples (stable fewest-events rank).

    Samples that were never correctly predicted count as "infinitely
    forgettable" (Toneva et al. keep them): they score +inf events.
    """
    events = state.forget_events.astype(jnp.float32)
    ever_correct = state.pa | (state.forget_events > 0)
    scores = jnp.where(ever_correct, events, jnp.inf)
    return planops.topk_hide(scores, k, mesh=mesh)


class ForgetSampler:
    def __init__(self, num_samples: int, config: ForgetConfig | None = None,
                 seed: int = 0, ctx: ParallelCtx | None = None):
        self.config = config or ForgetConfig()
        self.ctx = ctx or ParallelCtx()
        self.ctx.check_rows(num_samples)
        self.state: SampleState = self.ctx.shard_rows(
            init_sample_state(num_samples))
        self._key = self.ctx.replicate(planops.strategy_key(seed, "forget"))
        self._observe = jax.jit(scatter_observations)
        # True = removed; device-resident like the rest of the plan inputs.
        self.pruned_mask = self.ctx.shard_rows(
            jnp.zeros((num_samples,), bool))
        self.restarted = False

    @property
    def should_restart(self) -> bool:
        """True exactly once, after warmup finishes: caller re-inits the model."""
        return self.restarted

    def begin_epoch(self, epoch: int) -> np.ndarray:
        """Visible shuffled indices. ``epoch`` counts total epochs elapsed."""
        if epoch == self.config.warmup_epochs and not self.restarted:
            self._prune()
            self.restarted = True
        else:
            self.restarted = False
        self._key, sub = jax.random.split(self._key)
        order, num_pruned = planops.masked_order(sub, self.pruned_mask,
                                                 mesh=self.ctx.mesh)
        # The single host sync of the epoch: the shuffled order + count.
        order, num_pruned = jax.device_get((order, num_pruned))
        n = self.state.num_samples
        return np.asarray(order[: n - int(num_pruned)])

    def _prune(self) -> None:
        n = self.state.num_samples
        k = int(np.floor(self.config.fraction * n))
        self.pruned_mask = self.ctx.shard_rows(
            _prune_step(self.state, jnp.int32(k), mesh=self.ctx.mesh))

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        self.state = self._observe(self.state, jnp.asarray(indices), loss, pa,
                                   pc, epoch)

    def batches(self, epoch_indices: np.ndarray, batch_size: int) -> Iterator[np.ndarray]:
        for start in range(0, len(epoch_indices) - batch_size + 1, batch_size):
            yield epoch_indices[start : start + batch_size]


@register_strategy("forget")
class ForgetStrategy(SampleStrategy):
    """Warmup -> prune-unforgettables -> restart, as one plan() flag."""

    config_cls, config_field = ForgetConfig, "forget"
    fused_observe = staticmethod(scatter_observations)

    def __init__(self, num_samples: int, config: ForgetConfig | None = None,
                 seed: int = 0, ctx: ParallelCtx | None = None):
        super().__init__(num_samples, config, seed)
        self._inner = ForgetSampler(num_samples, config, seed, ctx=ctx)

    @property
    def state(self) -> SampleState:
        return self._inner.state

    def get_device_state(self) -> SampleState:
        return self._inner.state

    def set_device_state(self, state: SampleState) -> None:
        self._inner.state = state

    def plan(self, epoch: int) -> EpochPlan:
        idx = self._inner.begin_epoch(epoch)
        # begin_epoch materialises the shuffled order (and, at the prune
        # epoch, the device-ranked prune mask) with one device_get.
        return EpochPlan(epoch=epoch, visible_indices=idx,
                         reinit_model=self._inner.should_restart,
                         host_syncs=1)

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        self._inner.observe(indices, loss, pa, pc, epoch)

    def state_dict(self) -> dict:
        return {"arrays": {"state": self._inner.state,
                           "pruned": self._inner.pruned_mask,
                           "rng_key": planops.key_data(self._inner._key)},
                "host": {"rng_impl": planops.KEY_IMPL,
                         "restarted": bool(self._inner.restarted)}}

    def load_state_dict(self, state: dict) -> None:
        self._inner.state = self._inner.ctx.shard_rows(
            jax.tree.map(jnp.asarray, state["arrays"]["state"]))
        self._inner.pruned_mask = self._inner.ctx.shard_rows(
            jnp.asarray(np.asarray(state["arrays"]["pruned"], bool)))
        self._inner.restarted = bool(state["host"]["restarted"])
        # restore_key also migrates pre-PlanOps checkpoints (host numpy RNG).
        self._inner._key = self._inner.ctx.replicate(
            planops.restore_key(state, self.seed, "forget"))
