"""Online FORGET baseline (paper Sec. 4; Toneva et al. [13]).

Train ``warmup_epochs`` (paper: 20) on the full dataset while counting
*forgetting events* (correct -> incorrect transitions, maintained for free in
SampleState).  Then prune the fraction F of the *least-forgettable* samples
(fewest forgetting events, ties broken by never-misclassified first) and
restart training from epoch 0 on the pruned set.  Total reported cost must
include the warmup epochs (paper Sec. 4.2).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state import SampleState, init_sample_state, scatter_observations
from repro.core.strategy import (
    EpochPlan, SampleStrategy, register_strategy, rng_state, set_rng_state,
)


@dataclasses.dataclass
class ForgetConfig:
    fraction: float = 0.3
    warmup_epochs: int = 20


class ForgetSampler:
    def __init__(self, num_samples: int, config: ForgetConfig | None = None,
                 seed: int = 0):
        self.config = config or ForgetConfig()
        self.state: SampleState = init_sample_state(num_samples)
        self._rng = np.random.default_rng(seed)
        self._observe = jax.jit(scatter_observations)
        self.pruned_mask = np.zeros(num_samples, bool)  # True = removed
        self.restarted = False

    @property
    def should_restart(self) -> bool:
        """True exactly once, after warmup finishes: caller re-inits the model."""
        return self.restarted

    def begin_epoch(self, epoch: int) -> np.ndarray:
        """Visible shuffled indices. ``epoch`` counts total epochs elapsed."""
        if epoch == self.config.warmup_epochs and not self.restarted:
            self._prune()
            self.restarted = True
        else:
            self.restarted = False
        idx = np.arange(self.state.num_samples)[~self.pruned_mask]
        self._rng.shuffle(idx)
        return idx

    def _prune(self) -> None:
        events = np.asarray(self.state.forget_events).astype(np.float64)
        # Samples that were never correctly predicted count as "infinitely
        # forgettable" (Toneva et al. keep them): give them +inf events.
        ever_correct = np.asarray(self.state.pa) | (np.asarray(self.state.forget_events) > 0)
        events = np.where(ever_correct, events, np.inf)
        n = self.state.num_samples
        k = int(np.floor(self.config.fraction * n))
        order = np.argsort(events, kind="stable")  # fewest events first
        self.pruned_mask[order[:k]] = True

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        self.state = self._observe(self.state, jnp.asarray(indices), loss, pa,
                                   pc, epoch)

    def batches(self, epoch_indices: np.ndarray, batch_size: int) -> Iterator[np.ndarray]:
        for start in range(0, len(epoch_indices) - batch_size + 1, batch_size):
            yield epoch_indices[start : start + batch_size]


@register_strategy("forget")
class ForgetStrategy(SampleStrategy):
    """Warmup -> prune-unforgettables -> restart, as one plan() flag."""

    config_cls, config_field = ForgetConfig, "forget"
    fused_observe = staticmethod(scatter_observations)

    def __init__(self, num_samples: int, config: ForgetConfig | None = None,
                 seed: int = 0):
        super().__init__(num_samples, config, seed)
        self._inner = ForgetSampler(num_samples, config, seed)

    @property
    def state(self) -> SampleState:
        return self._inner.state

    def get_device_state(self) -> SampleState:
        return self._inner.state

    def set_device_state(self, state: SampleState) -> None:
        self._inner.state = state

    def plan(self, epoch: int) -> EpochPlan:
        idx = self._inner.begin_epoch(epoch)
        # begin_epoch reads forget-event counts at the prune epoch; count
        # the epoch boundary as one host sync like the other planners.
        return EpochPlan(epoch=epoch, visible_indices=idx,
                         reinit_model=self._inner.should_restart,
                         host_syncs=1)

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        self._inner.observe(indices, loss, pa, pc, epoch)

    def state_dict(self) -> dict:
        return {"arrays": {"state": self._inner.state,
                           "pruned": self._inner.pruned_mask},
                "host": {"rng": rng_state(self._inner._rng),
                         "restarted": bool(self._inner.restarted)}}

    def load_state_dict(self, state: dict) -> None:
        self._inner.state = jax.tree.map(jnp.asarray, state["arrays"]["state"])
        self._inner.pruned_mask = np.asarray(state["arrays"]["pruned"], bool)
        self._inner.restarted = bool(state["host"]["restarted"])
        set_rng_state(self._inner._rng, state["host"]["rng"])
