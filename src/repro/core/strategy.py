"""Unified sample-selection strategy protocol + registry.

Every way of choosing *which samples train this epoch* — KAKURENBO's
adaptive hiding and each baseline the paper compares against — implements
one interface, so the trainer, the benchmarks and the pjit launch path are
strategy-agnostic: adding a strategy is one registered class, zero trainer
edits.

The per-epoch contract (driven by ``train/trainer.py``):

  1. ``prepare(epoch, feats_fn)``  — optional pre-plan hook (e.g. Grad-Match
     recollects last-layer gradient features every R epochs).
  2. ``plan(epoch) -> EpochPlan``  — the epoch's visible index list plus
     LR scaling, the hidden list, and flags (``needs_refresh`` for
     KAKURENBO's step-D forward pass, ``reinit_model`` for FORGET's
     restart-after-warmup).  Planning math is device-resident, composed
     from ``core/planops.py`` ops and materialised with one
     ``jax.device_get``.
  3. per batch: ``batch_weights(indices)`` (static per-sample weights —
     ISWR/InfoBatch/Grad-Match, a plan-time lookup) and/or the in-step
     hooks fused into the jitted train step: ``fused_observe`` (bookkeeping
     scatter) and ``fused_select`` (Selective-Backprop's loss-dependent
     backward mask).
  4. ``observe(indices, loss, pa, pc, epoch)`` — lagging-loss bookkeeping
     from the training forward pass (host-dispatched legacy path; fused
     strategies only see it from the step-D refresh loop).
  5. ``on_epoch_end(plan, eval_forward, batch_size) -> int`` — end-of-epoch
     work (hidden-list refresh); returns extra forward-pass samples for the
     work accounting.
  6. ``state_dict()/load_state_dict()`` — checkpoint/restore, including the
     device plan RNG keys, so a restart resumes the exact trajectory.

Registration mirrors ``configs/registry.py``::

    @register_strategy("kakurenbo")
    class KakurenboStrategy(SampleStrategy):
        config_cls, config_field = KakurenboConfig, "kakurenbo"

    strategy = make_strategy("kakurenbo", num_samples, cfg, seed)

``docs/adding_a_strategy.md`` walks through building a strategy end-to-end;
``docs/paper_map.md`` maps every registered strategy (and every Section-3
concept of the paper) to the code implementing it — CI checks that any new
``@register_strategy`` name is documented there.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable

import numpy as np


@dataclasses.dataclass
class EpochPlan:
    """One epoch's sampling decision, consumable by any training loop
    (host trainer — single-device or mesh-sharded — or the pjit pod-scale
    step, see ``launch/train.py``).

    All index arrays are *host* numpy arrays of global sample ids: the plan
    is the device→host boundary of the selection engine (see
    ``docs/architecture.md``).  The arrays are *computed* on device — every
    registered strategy plans through the jitted ``core/planops.py`` ops —
    and materialised here once per epoch by a single ``jax.device_get``
    (counted in ``host_syncs``).
    """

    epoch: int
    visible_indices: np.ndarray            # shuffled training index list (host)
    hidden_indices: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))
    max_fraction: float = 0.0              # F_e (ceiling)
    hidden_fraction: float = 0.0           # F*_e (actual, after move-back)
    lr_scale: float = 1.0                  # Eq. 8 factor (1.0 = off)
    needs_refresh: bool = False            # run step-D refresh at epoch end
    reinit_model: bool = False             # restart model from scratch (FORGET)
    host_syncs: int = 0                    # device->host syncs spent planning
    #: Samples hidden last epoch that the move-back rule (Sec. 3.1) returned
    #: to this epoch's training list — i.e. ``hidden_{e-1} & ~hidden_e``.
    #: Sorted global ids; empty for strategies without move-back.
    moveback_indices: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))


EvalForward = Callable[[np.ndarray], tuple]   # indices -> (loss, pa, pc)
FeatsFn = Callable[[], tuple[np.ndarray, np.ndarray]]


class SampleStrategy:
    """Base class (and de-facto protocol) for sample-selection strategies.

    Subclasses override what they need; the defaults are the uniform
    baseline behaviours (no weights, no selection, no end-of-epoch work).

    Residency contract (see ``docs/architecture.md`` for the full picture):
    a strategy's *decisions* (the ``EpochPlan``) are host numpy; its
    *per-sample bookkeeping* may be device-resident (``get_device_state`` /
    ``fused_observe``), in which case it crosses the host boundary only at
    the epoch plan.  Under the mesh-sharded trainer
    (``TrainConfig.mesh_shape``) the device state is row-sharded over the
    ``("data",)`` mesh axis; everything a strategy computes from it must be
    either shard-local or explicit about its collectives (the KAKURENBO
    histogram plan psums O(bins) scalars — ``core/selection.py``).
    """

    name: str = "?"                        # filled in by @register_strategy
    config_cls: type | None = None         # dataclass type of the config
    config_field: str | None = None        # attr name on a composite config

    #: Device-resident observation hook: a *pure* function
    #: ``(state_pytree, indices, loss, pa, pc, epoch) -> state_pytree`` the
    #: trainer fuses into its jitted train step, so per-batch bookkeeping
    #: never leaves the device.  Shapes: ``indices`` (B,) i32 global sample
    #: ids, ``loss``/``pc`` (B,) f32, ``pa`` (B,) bool, ``epoch`` i32 scalar.
    #: Must be scatter-only (no cross-sample reductions): the mesh trainer
    #: runs it on a row-sharded state pytree under GSPMD, where a scatter
    #: lowers to an O(B) metrics gather + shard-local writes.  None = the
    #: trainer falls back to per-batch host-side ``observe()`` calls.
    #: Strategies exposing this must also implement
    #: ``get_device_state``/``set_device_state``.
    fused_observe: Callable | None = None

    #: Device-resident in-step selection hook: a *pure* function
    #: ``(state_pytree, loss) -> (weights, state_pytree)`` fused into the
    #: jitted train step *before* the backward pass.  ``loss`` is the (B,)
    #: f32 per-sample loss of a forward-only pass at the current params;
    #: ``weights`` (B,) f32 multiply the per-sample losses in the training
    #: objective (0 = dropped from the backward pass, counted out of
    #: ``bwd_samples``).  This is Selective-Backprop's forward-then-mask
    #: flow without the host round trip: any randomness draws from a PRNG
    #: key carried *inside* the state pytree, so the whole flow scans and
    #: checkpoints.  Under the mesh trainer the state is kept replicated
    #: (it is global history, not per-sample rows) and the loss vector is
    #: replicated before the hook runs, so selection is identical for every
    #: mesh size.  Strategies exposing this must also implement
    #: ``get_device_state``/``set_device_state``.
    fused_select: Callable | None = None

    def __init__(self, num_samples: int, config: Any = None, seed: int = 0):
        self.num_samples = num_samples
        self.config = config
        self.seed = seed

    # -- epoch boundary ------------------------------------------------------

    def prepare(self, epoch: int, feats_fn: FeatsFn | None = None) -> None:
        """Pre-plan hook, called on host before ``plan()`` every epoch.

        ``feats_fn`` lazily yields host ``(features (N, d), labels (N,))``
        — only Grad-Match consumes it (every R epochs); passing it never
        forces the feature forward pass by itself.
        """

    def plan(self, epoch: int) -> EpochPlan:
        """The epoch's sampling decision.  Host-side entry point; any device
        math inside (selection, shuffle) should batch its results into a
        single ``jax.device_get`` — the plan *is* the per-epoch host sync
        (count it in ``EpochPlan.host_syncs``)."""
        raise NotImplementedError

    # -- per-batch -----------------------------------------------------------

    def observe(self, indices, loss, pa, pc, epoch: int) -> None:
        """Record lagging (loss, PA, PC) from the training forward pass.

        Host-dispatched legacy path (one dispatch per batch): ``indices``
        (B,) global ids, ``loss``/``pc`` (B,) f32, ``pa`` (B,) bool, all
        device arrays or numpy.  Strategies with ``fused_observe`` only see
        this from the step-D refresh loop and the legacy-parity trainer path
        (``TrainConfig.fused_observe=False``).
        """

    @property
    def supports_scan(self) -> bool:
        """Can a whole epoch of this strategy run as jitted multi-step scan
        blocks (``train/engines.py::ScanEpochEngine``) with zero per-batch
        host work?

        True when the strategy needs nothing from the host between train
        steps: no host-side ``observe()`` (either it keeps no per-sample
        state, or the bookkeeping is expressible as ``fused_observe`` inside
        the step).  Loss-dependent selection does not block scanning either
        — it is the in-step ``fused_select`` hook.  ``batch_weights`` does
        NOT block scanning — it is a plan-time lookup by contract, so the
        engine pre-gathers every batch's weights into the epoch plan before
        dispatch.  Strategies that scan must keep these properties in sync
        with their hooks; the trainer additionally checks that the fused
        observe is actually active before picking the scanned engine
        (``TrainConfig.fused_observe=False`` forces the host loop).
        """
        observes = type(self).observe is not SampleStrategy.observe
        return not observes or self.fused_observe is not None

    def batch_weights(self, indices: np.ndarray) -> np.ndarray | None:
        """Static per-sample loss weights for this batch (None = uniform).

        Host numpy in, host numpy (B,) f32 out; looked up from plan-time
        decisions (ISWR unbiasing, InfoBatch 1/(1-r) rescale) — must not
        touch device state.  Loss-*dependent* per-batch weights are the
        in-step ``fused_select`` hook instead.
        """
        return None

    # -- device-resident state (fused_observe strategies) --------------------

    def get_device_state(self):
        """Pytree of device arrays consumed/produced by ``fused_observe`` /
        ``fused_select``.

        The trainer fetches this once after ``plan()``, threads it through
        the jitted train step for the whole epoch (donated, so the strategy's
        own reference may die mid-epoch), and hands the final value back via
        ``set_device_state`` — zero per-batch host round trips.  For
        ``fused_observe`` the leaves are ``(N, ...)`` per-sample arrays; the
        mesh trainer keeps them row-sharded over the data axes
        (``ParallelCtx.rows_spec``), so N must be a multiple of the
        data-parallel degree.  ``fused_select`` state (global history, PRNG
        key) is kept replicated instead.
        """
        return None

    def set_device_state(self, state) -> None:
        """Accept the (possibly sharded) state pytree back from the trainer
        at the epoch boundary (or after a mid-epoch crash — the trainer
        always hands back the latest live buffers for checkpointing)."""
        raise NotImplementedError(
            f"{type(self).__name__} declares no device-resident state")

    # -- epoch end -----------------------------------------------------------

    def on_epoch_end(self, plan: EpochPlan, eval_forward: EvalForward,
                     batch_size: int) -> int:
        """End-of-epoch work; returns extra forward-sample count.

        ``eval_forward`` maps host (b,) index arrays to device
        ``(loss, pa, pc)`` — KAKURENBO's step-D hidden refresh drives it in
        ``batch_size`` slices.  The return value feeds the paper's work
        accounting (forward-only samples), so padding must be excluded.
        """
        return 0

    # -- checkpoint/restore --------------------------------------------------

    def state_dict(self) -> dict:
        """``{"arrays": <pytree of arrays>, "host": <json-able dict>}``.

        The arrays part must have a construction-time-stable tree structure
        (it becomes checkpoint leaves); host carries RNG states and flags.
        Restoring must be bit-exact: a resumed run replays the identical
        shuffle/selection trajectory (tested by
        ``test_checkpoint_restart_bit_exact``).
        """
        return {"arrays": {}, "host": {}}

    def load_state_dict(self, state: dict) -> None:
        if state.get("arrays") or state.get("host"):
            raise ValueError(
                f"{type(self).__name__} has no state to restore into")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

STRATEGIES: dict[str, type[SampleStrategy]] = {}


def register_strategy(name: str):
    """Class decorator: ``@register_strategy("kakurenbo")``."""

    def deco(cls: type[SampleStrategy]) -> type[SampleStrategy]:
        if name in STRATEGIES and STRATEGIES[name] is not cls:
            raise ValueError(f"strategy {name!r} already registered")
        cls.name = name
        STRATEGIES[name] = cls
        return cls

    return deco


def _ensure_registered() -> None:
    # Importing the package pulls in every strategy module (core/__init__.py),
    # which runs the @register_strategy decorators.
    import repro.core  # noqa: F401


def available_strategies() -> list[str]:
    _ensure_registered()
    return sorted(STRATEGIES)


def make_strategy(name: str, num_samples: int, cfg: Any = None,
                  seed: int = 0, **extras: Any) -> SampleStrategy:
    """Build a registered strategy.

    ``cfg`` may be the strategy's own config dataclass or any composite
    object carrying it as attribute ``cls.config_field`` (e.g. the
    trainer's ``TrainConfig``).  ``extras`` (``num_classes``,
    ``total_epochs``, ...) are forwarded only to strategies whose
    constructor declares them, so callers can pass a superset.
    """
    _ensure_registered()
    if name not in STRATEGIES:
        raise ValueError(
            f"unknown strategy {name!r}; known: {available_strategies()}")
    cls = STRATEGIES[name]
    if cls.config_cls is None:
        cfg_obj = None                       # strategy takes no config
    elif cfg is None or isinstance(cfg, cls.config_cls):
        cfg_obj = cfg
    else:
        # Composite config: must actually carry the right field — silently
        # falling back to defaults would report results under wrong
        # hyperparameters.
        cfg_obj = getattr(cfg, cls.config_field or "", None)
        if not isinstance(cfg_obj, cls.config_cls):
            raise TypeError(
                f"cfg for strategy {name!r} must be {cls.config_cls.__name__}"
                f" or carry a .{cls.config_field} of that type; got "
                f"{type(cfg).__name__}")
    params = inspect.signature(cls.__init__).parameters
    kw = {k: v for k, v in extras.items() if k in params}
    return cls(num_samples, cfg_obj, seed=seed, **kw)


# ---------------------------------------------------------------------------
# Shared helpers for strategy implementations
# ---------------------------------------------------------------------------


def rng_state(rng: np.random.Generator) -> dict:
    return rng.bit_generator.state


def set_rng_state(rng: np.random.Generator, state: dict) -> None:
    rng.bit_generator.state = state
