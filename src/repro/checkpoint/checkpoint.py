"""Fault-tolerant checkpointing: atomic, integrity-checked, async-capable.

Layout: ``<dir>/step_<n>/`` containing one ``.npy`` per pytree leaf plus a
``manifest.json`` with the treedef, per-leaf CRC32 and metadata.  A
``COMMITTED`` marker is written last, after fsync, so a crash mid-save never
yields a checkpoint that ``latest_step`` would pick up (write-ahead commit).
In a multi-host deployment each host writes its own param shards under
``host_<k>`` with the same protocol; here (single process) there is one host.

Resilience on top of the commit protocol (``docs/fault_tolerance.md``):

- ``save`` retries transient I/O errors with exponential backoff (the tmp
  dir is cleaned between attempts, so a retry restarts the write-ahead
  protocol from scratch and the atomicity guarantee holds).
- ``save_async`` returns an :class:`AsyncSaveHandle` whose ``join()`` /
  ``result()`` re-raise the worker thread's failure — a failed background
  save can no longer masquerade as success (the Trainer joins the handle
  before GC'ing older checkpoints).
- ``restore_latest`` walks the committed chain newest-first: a checkpoint
  that fails its CRC / has an unreadable leaf or manifest is *quarantined*
  (renamed ``corrupt_<name>``, so ``latest_step`` and ``_gc`` never touch
  it again) with a logged warning, and the restore falls back to the next
  committed step.  A structure mismatch (a valid checkpoint from a
  different config) falls back without quarantining.
"""
from __future__ import annotations

import json
import logging
import os
import shutil
import threading
import time
import zlib
from typing import Any

import jax
import numpy as np

logger = logging.getLogger("repro.checkpoint")


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _write_leaf(path: str, arr: np.ndarray) -> None:
    """Single-leaf write, the unit of save I/O.

    The indirection is the fault-injection seam: ``train/chaos.py``
    patches this to simulate failing disks (``failing_leaf_writes``).
    """
    np.save(path, arr)


def _write_dir(tmp: str, final: str, step: int, arrays: list[np.ndarray],
               treedef, metadata: dict | None) -> None:
    """One attempt of the write-ahead commit protocol into ``tmp``."""
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(arrays),
        "metadata": metadata or {},
        "crc": [],
        "dtype": [],
    }
    for i, arr in enumerate(arrays):
        manifest["crc"].append(zlib.crc32(np.ascontiguousarray(arr).tobytes()))
        manifest["dtype"].append(str(arr.dtype))
        _write_leaf(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, "COMMITTED"), "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)


def save(directory: str, step: int, tree: Any, metadata: dict | None = None,
         keep: int | None = 3, retries: int = 2,
         retry_backoff: float = 0.05, _sleep=time.sleep) -> str:
    """Atomically save ``tree`` for ``step``. Returns the checkpoint path.

    Transient ``OSError`` during the write is retried up to ``retries``
    times with exponential backoff (``retry_backoff * 2**attempt`` seconds);
    each attempt restarts the write-ahead protocol in a clean tmp dir, so a
    partially-written attempt can never be committed.  ``keep=None``
    disables the trailing GC (the Trainer's async mode GCs explicitly,
    after the save is confirmed).
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    leaves, treedef = _flatten(tree)
    # Device -> host once, outside the retry loop.
    arrays = [np.asarray(jax.device_get(leaf)) for leaf in leaves]
    last_exc: OSError | None = None
    for attempt in range(retries + 1):
        try:
            _write_dir(tmp, final, step, arrays, treedef, metadata)
            break
        except OSError as e:
            last_exc = e
            shutil.rmtree(tmp, ignore_errors=True)
            if attempt < retries:
                delay = retry_backoff * (2 ** attempt)
                logger.warning(
                    "checkpoint save step %d attempt %d/%d failed (%s) — "
                    "retrying in %.2fs", step, attempt + 1, retries + 1, e,
                    delay)
                _sleep(delay)
    else:
        logger.error("checkpoint save step %d failed after %d attempts: %s",
                     step, retries + 1, last_exc)
        raise last_exc
    if keep:
        _gc(directory, keep)
    return final


class AsyncSaveHandle:
    """Handle for a background checkpoint save.

    ``join()`` waits for the worker and *re-raises* its failure — a failed
    async save is no longer silent.  ``result()`` additionally returns the
    committed path.  Thread-API compatible (``join``/``is_alive``) with the
    bare ``threading.Thread`` this used to return.
    """

    def __init__(self, path: str, target, args):
        self.path = path
        self._exc: BaseException | None = None
        self._result: str | None = None

        def _run():
            try:
                self._result = target(*args)
            except BaseException as e:  # noqa: BLE001 — re-raised in join()
                self._exc = e

        self._thread = threading.Thread(target=_run)
        self._thread.start()

    def is_alive(self) -> bool:
        return self._thread.is_alive()

    def done(self) -> bool:
        return not self._thread.is_alive()

    def exception(self) -> BaseException | None:
        """Wait and return (not raise) the worker's exception, if any."""
        self._thread.join()
        return self._exc

    def join(self, timeout: float | None = None) -> None:
        self._thread.join(timeout)
        if self._exc is not None:
            raise self._exc

    def result(self, timeout: float | None = None) -> str:
        self.join(timeout)
        return self._result


def save_async(directory: str, step: int, tree: Any,
               metadata: dict | None = None,
               keep: int | None = 3) -> AsyncSaveHandle:
    """Snapshot to host memory synchronously, write to disk in a thread —
    training continues while I/O happens (the standard async-ckpt split).

    Returns an :class:`AsyncSaveHandle`; call ``join()``/``result()`` to
    surface save failures (the old API returned a bare ``Thread`` that
    swallowed them).
    """
    snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    path = os.path.join(directory, f"step_{step:010d}")
    return AsyncSaveHandle(path, save,
                           (directory, step, snapshot, metadata, keep))


def _valid(path: str) -> bool:
    return (os.path.isdir(path)
            and os.path.exists(os.path.join(path, "COMMITTED"))
            and os.path.exists(os.path.join(path, "manifest.json")))


def _committed_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and _valid(os.path.join(directory, name)):
            steps.append(int(name.split("_")[1]))
    return sorted(steps)


def latest_step(directory: str) -> int | None:
    steps = _committed_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, like: Any,
            check_integrity: bool = True) -> tuple[Any, dict]:
    """Restore into the structure of ``like``. Returns (tree, metadata).

    Raises ``FileNotFoundError`` (no committed dir), ``ValueError``
    (structure mismatch vs ``like``), or ``IOError`` (CRC mismatch or an
    unreadable/corrupt leaf or manifest).
    """
    path = os.path.join(directory, f"step_{step:010d}")
    if not _valid(path):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    except json.JSONDecodeError as e:
        raise IOError(f"corrupt manifest in {path}: {e}") from e
    leaves, treedef = _flatten(like)
    if manifest["num_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['num_leaves']} leaves, expected "
            f"{len(leaves)} — structure mismatch")
    out = []
    for i, ref in enumerate(leaves):
        leaf_path = os.path.join(path, f"leaf_{i:05d}.npy")
        try:
            arr = np.load(leaf_path)
        except (ValueError, EOFError, OSError) as e:
            # Truncated/garbled .npy — integrity, not structure.
            raise IOError(f"unreadable leaf {i} of {path}: {e}") from e
        if check_integrity:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != manifest["crc"][i]:
                raise IOError(f"CRC mismatch on leaf {i} of {path} — corrupt")
        out.append(arr)
    return jax.tree.unflatten(treedef, out), manifest["metadata"]


def _quarantine(directory: str, step: int) -> str | None:
    """Rename a corrupt ``step_<n>`` dir to ``corrupt_<...>``.

    The prefix swap takes it out of ``latest_step``'s and ``_gc``'s view
    (both filter on ``step_``) while preserving the bytes for forensics.
    """
    name = f"step_{step:010d}"
    src = os.path.join(directory, name)
    dst = os.path.join(directory, f"corrupt_{name}")
    n = 0
    while os.path.exists(dst):
        n += 1
        dst = os.path.join(directory, f"corrupt_{name}.{n}")
    try:
        os.rename(src, dst)
    except OSError as e:  # pragma: no cover — quarantine is best-effort
        logger.warning("could not quarantine %s: %s", src, e)
        return None
    return dst


def restore_latest(directory: str, like: Any,
                   fallback: bool = True) -> tuple[Any, dict, int] | None:
    """Restore the newest *good* committed checkpoint.

    Walks the committed chain newest-first: integrity failures (CRC
    mismatch, unreadable leaf/manifest) quarantine the dir — renamed
    ``corrupt_<name>`` with a logged warning — and fall back to the next
    committed step; structure mismatches fall back without quarantining
    (the checkpoint is fine, the config changed).  Returns ``None`` with no
    committed checkpoint at all; re-raises the *newest* checkpoint's error
    when every candidate fails (so single-checkpoint behaviour is unchanged
    from the pre-fallback API).  ``fallback=False`` restores only the
    newest committed step, failures propagating directly.
    """
    first_exc: Exception | None = None
    for step in reversed(_committed_steps(directory)):
        try:
            tree, meta = restore(directory, step, like)
            return tree, meta, step
        except IOError as e:
            if not fallback:
                raise
            first_exc = first_exc or e
            dst = _quarantine(directory, step)
            logger.warning(
                "corrupt checkpoint step %d (%s)%s — falling back to the "
                "previous committed step", step, e,
                f"; quarantined to {dst}" if dst else "")
        except ValueError as e:
            if not fallback:
                raise
            first_exc = first_exc or e
            logger.warning(
                "checkpoint step %d structure mismatch (%s) — falling back "
                "to the previous committed step", step, e)
    if first_exc is not None:
        raise first_exc
    return None


def gc(directory: str, keep: int = 3) -> None:
    """Drop all but the newest ``keep`` committed checkpoints.

    Public so the Trainer's async mode can defer GC until a newer save's
    handle has been joined successfully (never delete the fallback chain
    before its replacement is confirmed on disk).
    """
    _gc(directory, keep)


def _gc(directory: str, keep: int) -> None:
    names = sorted(n for n in os.listdir(directory) if n.startswith("step_")
                   and not n.endswith(".tmp"))
    for name in names[:-keep]:
        shutil.rmtree(os.path.join(directory, name), ignore_errors=True)
