"""Fault-tolerant checkpointing: atomic, integrity-checked, async-capable.

Layout: ``<dir>/step_<n>/`` containing one ``.npy`` per pytree leaf plus a
``manifest.json`` with the treedef, per-leaf CRC32 and metadata.  A
``COMMITTED`` marker is written last, after fsync, so a crash mid-save never
yields a checkpoint that ``latest_step`` would pick up (write-ahead commit).
In a multi-host deployment each host writes its own param shards under
``host_<k>`` with the same protocol; here (single process) there is one host.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree: Any, metadata: dict | None = None,
         keep: int = 3) -> str:
    """Atomically save ``tree`` for ``step``. Returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "metadata": metadata or {},
        "crc": [],
        "dtype": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        manifest["crc"].append(zlib.crc32(np.ascontiguousarray(arr).tobytes()))
        manifest["dtype"].append(str(arr.dtype))
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, "COMMITTED"), "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def save_async(directory: str, step: int, tree: Any,
               metadata: dict | None = None, keep: int = 3) -> threading.Thread:
    """Snapshot to host memory synchronously, write to disk in a thread —
    training continues while I/O happens (the standard async-ckpt split)."""
    snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    t = threading.Thread(
        target=save, args=(directory, step, snapshot, metadata, keep))
    t.start()
    return t


def _valid(path: str) -> bool:
    return (os.path.isdir(path)
            and os.path.exists(os.path.join(path, "COMMITTED"))
            and os.path.exists(os.path.join(path, "manifest.json")))


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and _valid(os.path.join(directory, name)):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, step: int, like: Any,
            check_integrity: bool = True) -> tuple[Any, dict]:
    """Restore into the structure of ``like``. Returns (tree, metadata)."""
    path = os.path.join(directory, f"step_{step:010d}")
    if not _valid(path):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like)
    if manifest["num_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['num_leaves']} leaves, expected "
            f"{len(leaves)} — structure mismatch")
    out = []
    for i, ref in enumerate(leaves):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        if check_integrity:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != manifest["crc"][i]:
                raise IOError(f"CRC mismatch on leaf {i} of {path} — corrupt")
        out.append(arr)
    return jax.tree.unflatten(treedef, out), manifest["metadata"]


def restore_latest(directory: str, like: Any) -> tuple[Any, dict, int] | None:
    step = latest_step(directory)
    if step is None:
        return None
    tree, meta = restore(directory, step, like)
    return tree, meta, step


def _gc(directory: str, keep: int) -> None:
    names = sorted(n for n in os.listdir(directory) if n.startswith("step_")
                   and not n.endswith(".tmp"))
    for name in names[:-keep]:
        shutil.rmtree(os.path.join(directory, name), ignore_errors=True)
