from repro.data.synthetic import SyntheticClassification, SyntheticLM  # noqa: F401
from repro.data.pipeline import Pipeline, worker_slice  # noqa: F401
