"""Sharded input pipeline.

Maps global sample indices (produced by the samplers in ``repro.core``) to
device batches.  In a multi-host deployment each process owns a deterministic
contiguous shard of every epoch's index list — ``worker_slice`` is the single
source of truth for that mapping, which is what makes elastic rescaling
bit-exact: resizing from P to P' workers re-runs the same function with the
same epoch permutation (see train/fault.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator

import numpy as np


def epoch_index_plan(indices: np.ndarray, batch_size: int,
                     pad_final: bool = True) -> np.ndarray:
    """The epoch's batch layout as one ``(num_steps, batch_size)`` array.

    Row ``i`` is exactly the index list ``Pipeline.batches`` yields for batch
    ``i`` — full batches in order, then (with ``pad_final``) the trailing
    partial batch padded by cycling from the front of the already-shuffled
    epoch.  ``Pipeline.batches`` itself iterates this plan, so the host-loop
    and scanned epoch engines assemble bit-identical batches by construction.
    An index list shorter than one batch yields a ``(0, batch_size)`` plan.
    """
    bs = batch_size
    n_full = len(indices) // bs
    rows = [np.asarray(indices[: n_full * bs]).reshape(n_full, bs)]
    rem = len(indices) - n_full * bs
    if rem and pad_final and len(indices) >= bs:
        rows.append(np.concatenate(
            [indices[n_full * bs :], indices[: bs - rem]])[None])
    return np.concatenate(rows, axis=0) if len(rows) > 1 else rows[0]


def materialize(get_fn: Callable[[np.ndarray], dict], num_samples: int,
                chunk: int = 4096) -> dict:
    """Assemble the full dataset as host arrays, in ``chunk``-row pieces.

    The device-resident placement path of the scanned epoch engine: every
    per-index-deterministic dataset (the ``dataset.get`` contract) can be
    materialised once and thereafter batched by on-device gather instead of
    per-batch host assembly + H2D copies.  Chunking bounds the transient
    memory of generator-style datasets (``data/synthetic.py`` builds each
    row from its per-sample seed).
    """
    parts = []
    for start in range(0, num_samples, chunk):
        parts.append(get_fn(np.arange(start, min(start + chunk, num_samples))))
    if len(parts) == 1:
        return parts[0]
    return {k: np.concatenate([p[k] for p in parts], axis=0)
            for k in parts[0]}


def worker_slice(indices: np.ndarray, world_size: int, rank: int,
                 batch_size_per_worker: int) -> np.ndarray:
    """Deterministic per-worker view of an epoch index list.

    Trims to a multiple of (world_size * batch) then strides by rank so each
    global batch is the union of worker sub-batches — the same layout a
    pjit-sharded (global-batch) array has over the data axes.
    """
    gb = world_size * batch_size_per_worker
    usable = (len(indices) // gb) * gb
    trimmed = indices[:usable].reshape(-1, world_size, batch_size_per_worker)
    return trimmed[:, rank, :].reshape(-1)


@dataclasses.dataclass
class Pipeline:
    """Host-side batch assembly with optional double-buffering."""

    get_fn: Callable[[np.ndarray], dict]    # dataset.get
    batch_size: int

    pad_final: bool = True

    def batches(self, indices: np.ndarray) -> Iterator[tuple[np.ndarray, dict]]:
        """Full batches; the trailing partial batch is padded by cycling from
        the (already shuffled) front of the epoch instead of being dropped —
        dropping it would quantize away up to B-1 samples' worth of SGD steps,
        which at small N visibly distorts the hidden-fraction accounting.
        The batch layout is ``epoch_index_plan`` — the same plan the scanned
        epoch engine ships to device — so the two assembly paths agree row
        for row."""
        for idx in epoch_index_plan(np.asarray(indices), self.batch_size,
                                    self.pad_final):
            yield idx, self.get_fn(idx)

    def padded_batch(self, indices: np.ndarray) -> tuple[np.ndarray, dict, int]:
        """Batch from a possibly-short index list (pads by repeating last)."""
        n = len(indices)
        if n == 0:
            raise ValueError("empty batch")
        if n < self.batch_size:
            pad = np.full(self.batch_size - n, indices[-1])
            indices = np.concatenate([indices, pad])
        return indices, self.get_fn(indices), n
