"""Sharded input pipeline.

Maps global sample indices (produced by the samplers in ``repro.core``) to
device batches.  In a multi-host deployment each process owns a deterministic
contiguous shard of every epoch's index list — ``worker_slice`` is the single
source of truth for that mapping, which is what makes elastic rescaling
bit-exact: resizing from P to P' workers re-runs the same function with the
same epoch permutation (see train/fault.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator

import numpy as np


def worker_slice(indices: np.ndarray, world_size: int, rank: int,
                 batch_size_per_worker: int) -> np.ndarray:
    """Deterministic per-worker view of an epoch index list.

    Trims to a multiple of (world_size * batch) then strides by rank so each
    global batch is the union of worker sub-batches — the same layout a
    pjit-sharded (global-batch) array has over the data axes.
    """
    gb = world_size * batch_size_per_worker
    usable = (len(indices) // gb) * gb
    trimmed = indices[:usable].reshape(-1, world_size, batch_size_per_worker)
    return trimmed[:, rank, :].reshape(-1)


@dataclasses.dataclass
class Pipeline:
    """Host-side batch assembly with optional double-buffering."""

    get_fn: Callable[[np.ndarray], dict]    # dataset.get
    batch_size: int

    pad_final: bool = True

    def batches(self, indices: np.ndarray) -> Iterator[tuple[np.ndarray, dict]]:
        """Full batches; the trailing partial batch is padded by cycling from
        the (already shuffled) front of the epoch instead of being dropped —
        dropping it would quantize away up to B-1 samples' worth of SGD steps,
        which at small N visibly distorts the hidden-fraction accounting."""
        bs = self.batch_size
        n_full = len(indices) // bs
        for start in range(0, n_full * bs, bs):
            idx = indices[start : start + bs]
            yield idx, self.get_fn(idx)
        rem = len(indices) - n_full * bs
        if rem and self.pad_final and len(indices) >= bs:
            idx = np.concatenate([indices[n_full * bs:], indices[: bs - rem]])
            yield idx, self.get_fn(idx)

    def padded_batch(self, indices: np.ndarray) -> tuple[np.ndarray, dict, int]:
        """Batch from a possibly-short index list (pads by repeating last)."""
        n = len(indices)
        if n == 0:
            raise ValueError("empty batch")
        if n < self.batch_size:
            pad = np.full(self.batch_size - n, indices[-1])
            indices = np.concatenate([indices, pad])
        return indices, self.get_fn(indices), n
