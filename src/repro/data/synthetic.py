"""Synthetic datasets with a controlled easy/hard split.

KAKURENBO's dynamics are only interesting when sample importance varies, so
both datasets assign each sample a difficulty in [0, 1]:

* ``SyntheticClassification`` — class-template images + noise whose magnitude
  grows with difficulty; easy samples become confidently-correct quickly
  (candidates for hiding), hard samples keep a high loss (paper App. C.1's
  loss-histogram behaviour).  A small label-noise fraction models the
  DeepCAM top-2%% "unlearnable" tail (App. D / DropTop).

* ``SyntheticLM`` — token sequences mixing a deterministic k-gram source with
  uniform noise tokens; the noise fraction is the difficulty.

Everything is generated deterministically from a seed, in memory (the
container is offline), and indexed by global sample id — the contract the
sharded pipeline and the samplers rely on.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticClassification:
    num_samples: int = 4096
    num_classes: int = 10
    image_size: int = 16
    channels: int = 3
    easy_fraction: float = 0.6
    label_noise: float = 0.02
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        n, c, hw = self.num_samples, self.num_classes, self.image_size
        self.templates = rng.normal(0, 1, (c, hw, hw, self.channels)).astype(np.float32)
        self.labels = rng.integers(0, c, n).astype(np.int64)
        # difficulty: easy ~ U[0, .3], hard ~ U[.5, 1]
        easy = rng.random(n) < self.easy_fraction
        self.difficulty = np.where(
            easy, rng.uniform(0.0, 0.3, n), rng.uniform(0.5, 1.0, n)
        ).astype(np.float32)
        self.noise_seed = rng.integers(0, 2**31, n)
        flip = rng.random(n) < self.label_noise
        self.true_labels = self.labels.copy()
        self.labels[flip] = rng.integers(0, c, flip.sum())
        self.is_noisy = flip

    def arrays(self, chunk: int = 4096) -> dict:
        """Full dataset as arrays (device placement path of the scanned epoch
        engine).  Rows are per-index deterministic — each image depends only
        on its own ``noise_seed`` — so gathering rows from this
        materialisation is bit-identical to per-batch ``get`` assembly."""
        from repro.data.pipeline import materialize
        return materialize(self.get, self.num_samples, chunk)

    def get(self, indices: np.ndarray) -> dict:
        imgs = np.empty((len(indices), self.image_size, self.image_size,
                         self.channels), np.float32)
        for i, idx in enumerate(indices):
            r = np.random.default_rng(int(self.noise_seed[idx]))
            d = self.difficulty[idx]
            imgs[i] = (self.templates[self.true_labels[idx]] * (1.0 - 0.5 * d)
                       + r.normal(0, 0.3 + 1.2 * d, imgs[i].shape))
        return {"images": imgs, "labels": self.labels[indices].astype(np.int32)}

    # held-out set: same class templates (same task), fresh samples/noise
    def test_split(self, num: int = 1024) -> "SyntheticClassification":
        ds = SyntheticClassification(
            num, self.num_classes, self.image_size, self.channels,
            self.easy_fraction, 0.0, self.seed + 10_000)
        ds.templates = self.templates
        return ds


@dataclasses.dataclass
class SyntheticLM:
    num_samples: int = 2048
    seq_len: int = 128
    vocab_size: int = 257
    easy_fraction: float = 0.6
    order: int = 3          # k-gram order of the deterministic source
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        n = self.num_samples
        # deterministic k-gram transition table
        self.table = rng.integers(
            0, self.vocab_size, (self.vocab_size,) * self.order).astype(np.int32)
        easy = rng.random(n) < self.easy_fraction
        self.difficulty = np.where(
            easy, rng.uniform(0.0, 0.15, n), rng.uniform(0.4, 0.9, n)
        ).astype(np.float32)
        self.sample_seed = rng.integers(0, 2**31, n)

    def _gen_one(self, idx: int) -> np.ndarray:
        r = np.random.default_rng(int(self.sample_seed[idx]))
        s = self.seq_len + 1
        seq = np.empty(s, np.int32)
        seq[: self.order] = r.integers(0, self.vocab_size, self.order)
        noise = r.random(s) < self.difficulty[idx]
        for t in range(self.order, s):
            if noise[t]:
                seq[t] = r.integers(0, self.vocab_size)
            else:
                seq[t] = self.table[tuple(seq[t - self.order : t])]
        return seq

    def arrays(self, chunk: int = 4096) -> dict:
        """Full dataset as arrays (see ``SyntheticClassification.arrays``);
        sequences are per-index deterministic via ``sample_seed``."""
        from repro.data.pipeline import materialize
        return materialize(self.get, self.num_samples, chunk)

    def get(self, indices: np.ndarray) -> dict:
        seqs = np.stack([self._gen_one(int(i)) for i in indices])
        return {
            "tokens": seqs[:, :-1],
            "labels": seqs[:, 1:].astype(np.int32),
            "mask": np.ones((len(indices), self.seq_len), bool),
        }

    def test_split(self, num: int = 512) -> "SyntheticLM":
        ds = SyntheticLM(num, self.seq_len, self.vocab_size,
                         self.easy_fraction, self.order, self.seed + 10_000)
        ds.table = self.table  # same source process, fresh samples
        return ds
