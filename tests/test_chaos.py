"""Chaos suite: deterministic fault injection across the training stack.

The resilience acceptance bar (ISSUE 9): for every registered strategy
under both epoch engines, an injected mid-epoch crash must recover
*bit-exactly* through the checkpoint/restart supervisor; an injected NaN
batch must train to finite params with the poisoned sample quarantined
from the hiding plan; a corrupt newest checkpoint must fall back to the
prior committed step with a logged quarantine; failing save I/O must
retry or surface.  All injectors are seeded/counted (``train/chaos.py``)
— every failure fires at the same place on every run.
"""
from __future__ import annotations

import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.core import ForgetConfig, KakurenboConfig, LRSchedule
from repro.data import SyntheticClassification
from repro.models import cnn
from repro.train import Trainer, TrainConfig, chaos, fault, guard

CFG_MODEL = cnn.CNNConfig(image_size=8, widths=(8,), hidden=16)

ALL_STRATEGIES = ("baseline", "forget", "gradmatch", "infobatch", "iswr",
                  "kakurenbo", "random", "sb")
ENGINES = ("host", "scan")


def _fns():
    def init_params(rng):
        return cnn.init(rng, CFG_MODEL)

    def loss_fn(params, batch):
        logits = cnn.forward(params, CFG_MODEL, batch["images"])
        loss, pa, pc = cnn.per_sample_metrics(logits, batch["labels"])
        w = batch.get("weight")
        scalar = jnp.mean(loss * w) if w is not None else jnp.mean(loss)
        return scalar, (loss, pa, pc)

    return init_params, loss_fn


def _mk(engine, strategy="kakurenbo", epochs=3, num_samples=192, seed=0,
        checkpoint_dir=None, ds=None, **tc_kw):
    ds = ds or SyntheticClassification(num_samples=num_samples, image_size=8,
                                       seed=0)
    init_params, loss_fn = _fns()
    tc = TrainConfig(
        epochs=epochs, batch_size=64, strategy=strategy, engine=engine,
        lr=LRSchedule(0.05, "cosine", epochs, 1),
        kakurenbo=KakurenboConfig(max_fraction=0.3,
                                  fraction_milestones=(0, 1, 2, 3)),
        forget=ForgetConfig(fraction=0.3, warmup_epochs=2),
        seed=seed, checkpoint_dir=checkpoint_dir,
        checkpoint_every=1 if checkpoint_dir else 0, scan_steps=2, **tc_kw)
    return Trainer(tc, init_params, loss_fn, ds, None)


def _assert_state_equal(tr_a, tr_b, tag):
    for a, b in zip(jax.tree.leaves(tr_a.params),
                    jax.tree.leaves(tr_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=tag)
    sa = tr_a.strategy.get_device_state()
    sb = tr_b.strategy.get_device_state()
    if sa is not None:
        for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
            if jnp.issubdtype(a.dtype, jax.dtypes.prng_key):
                a, b = jax.random.key_data(a), jax.random.key_data(b)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=tag)


# --------------------------------------------------------------------------
# crash-at-step-k -> supervisor restart -> bit-exact recovery
# --------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_crash_recovery_bit_exact(strategy, engine, tmp_path):
    """Kill the trainer mid-epoch-1 at a fixed global step; the supervisor
    must restart from the epoch-1 checkpoint and land bit-identical —
    params AND strategy device state (incl. RNG keys) — to a run that
    never crashed.  The whole registry, both engines."""
    tag = f"{strategy}/{engine}"
    tr_ref = _mk(engine, strategy)
    tr_ref.run(3)

    builds = []

    def make():
        tr = _mk(engine, strategy, checkpoint_dir=str(tmp_path / "ckpt"))
        builds.append(tr)
        if len(builds) == 1:
            # 3 steps/epoch (192 samples / batch 64): step 4 is inside
            # epoch 1 — a genuine mid-epoch kill, not an epoch-boundary one.
            chaos.CrashAtStep(4).install(tr)
        return tr

    tr2, restarts = fault.run_with_restarts(make, 3, sleep_fn=lambda s: None)
    assert restarts == 1, tag
    assert builds[0] is not tr2 and len(builds) == 2, tag
    assert tr2.epoch == 3, tag
    _assert_state_equal(tr_ref, tr2, tag)


def test_crash_injector_fires_where_told(tmp_path):
    """The bomb's accounting: the host-engine bomb crashes before
    dispatching the requested step, the scan bomb before the block that
    would cover it."""
    tr = _mk("host", "baseline", checkpoint_dir=str(tmp_path / "h"))
    bomb = chaos.CrashAtStep(4).install(tr)
    with pytest.raises(chaos.ChaosError):
        tr.run(3)
    assert bomb.fired and bomb.steps_done == 4
    assert tr.epoch == 1   # epoch 0 completed + checkpointed

    tr = _mk("scan", "baseline", checkpoint_dir=str(tmp_path / "s"))
    bomb = chaos.CrashAtStep(4).install(tr)
    with pytest.raises(chaos.ChaosError):
        tr.run(3)
    # scan_steps=2: epoch 1's first block covers steps 3-4 -> crash before
    # it, at the scan engine's block granularity.
    assert bomb.fired and bomb.steps_done == 3
    assert tr.epoch == 1


# --------------------------------------------------------------------------
# NaN-in-batch -> numeric guard + score quarantine
# --------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_nan_batch_guard_and_quarantine(engine):
    """A poisoned sample must not reach params (update skipped) nor the
    hiding plan (observation quarantined: the sample keeps its never-seen
    sentinel state, so it stays maximally important and unhidden)."""
    poisoned = chaos.poison_samples(
        SyntheticClassification(num_samples=192, image_size=8, seed=0), [7])
    tr = _mk(engine, "kakurenbo", ds=poisoned, guard_policy="skip_update")
    hist = tr.run(3)
    for leaf in jax.tree.leaves(tr.params):
        assert np.isfinite(np.asarray(leaf)).all(), engine
    # every epoch sees the poisoned batch once
    assert [h.nonfinite_steps for h in hist] == [1, 1, 1], engine
    assert all(h.quarantined_observations >= 1 for h in hist), engine
    st = tr.strategy.get_device_state()
    assert float(st.loss[7]) == pytest.approx(1e9), engine
    assert int(st.seen[7]) == -1, engine
    assert not bool(st.hidden[7]), engine       # never in the hiding plan
    # the plan the *next* epoch would draw is finite and excludes 7
    plan = tr.strategy.plan(3)
    assert 7 not in np.asarray(plan.hidden_indices), engine
    assert 7 in np.asarray(plan.visible_indices), engine


def test_nan_batch_without_guard_poisons_params():
    """Control: guard off, the same poison propagates — the failure mode
    the guard exists for."""
    poisoned = chaos.poison_samples(
        SyntheticClassification(num_samples=192, image_size=8, seed=0), [7])
    tr = _mk("scan", "kakurenbo", ds=poisoned)
    tr.run(3)
    assert not all(np.isfinite(np.asarray(leaf)).all()
                   for leaf in jax.tree.leaves(tr.params))


@pytest.mark.parametrize("engine", ENGINES)
def test_guard_clean_run_bit_identical(engine):
    """On finite data the guarded step must be a bit-exact no-op — the
    skip_update containment may never perturb a healthy trajectory."""
    tr_off = _mk(engine, "kakurenbo")
    tr_on = _mk(engine, "kakurenbo", guard_policy="skip_update")
    h_off, h_on = tr_off.run(3), tr_on.run(3)
    assert [h.train_loss for h in h_off] == [h.train_loss for h in h_on]
    assert all(h.nonfinite_steps == 0 for h in h_on)
    _assert_state_equal(tr_off, tr_on, engine)
    # the guard rides the device carry: still one host sync per epoch
    assert all(h.host_syncs == 1 for h in h_on)


def test_guard_abort_after_consecutive_nonfinite():
    """With every batch poisoned, ``guard_abort_after`` must escalate to
    NonFiniteError at the epoch boundary — and the supervisor must class
    it restartable."""
    poisoned = chaos.poison_samples(
        SyntheticClassification(num_samples=192, image_size=8, seed=0),
        range(192))
    tr = _mk("scan", "baseline", ds=poisoned, guard_policy="skip_update",
             guard_abort_after=2)
    with pytest.raises(guard.NonFiniteError):
        tr.run(3)
    assert fault.classify_failure(guard.NonFiniteError("x")) == "restartable"
    # containment held even while aborting
    for leaf in jax.tree.leaves(tr.params):
        assert np.isfinite(np.asarray(leaf)).all()


# --------------------------------------------------------------------------
# corrupt-checkpoint-leaf -> CRC fallback chain
# --------------------------------------------------------------------------


def test_corrupt_newest_checkpoint_falls_back(tmp_path, caplog):
    """Bit-rot the newest committed checkpoint: restore must land on the
    prior committed step, quarantine the corrupt dir, and log it."""
    cdir = str(tmp_path / "ckpt")
    tr = _mk("scan", "kakurenbo", checkpoint_dir=cdir)
    tr.run(3)   # commits steps 1, 2, 3
    chaos.corrupt_checkpoint_leaf(cdir)   # newest = step 3

    tr2 = _mk("scan", "kakurenbo", checkpoint_dir=cdir)
    with caplog.at_level(logging.WARNING, logger="repro.checkpoint"):
        assert tr2.restore_latest()
    assert tr2.epoch == 2                         # prior committed step
    assert any("quarantined" in m for m in caplog.messages)
    names = sorted(p.name for p in (tmp_path / "ckpt").iterdir())
    assert "corrupt_step_0000000003" in names
    # the quarantined dir is invisible to latest_step and to future GC
    assert ckpt.latest_step(cdir) == 2
    # ...and the fallback restore resumes a working run
    tr2.run(3)
    assert tr2.epoch == 3


def test_corruption_injector_is_crc_detectable(tmp_path):
    """The injector flips payload bytes under an intact COMMITTED marker —
    exactly the silent-bit-rot shape only the CRC can catch."""
    tree = {"a": jnp.arange(64.0)}
    ckpt.save(str(tmp_path), 5, tree)
    chaos.corrupt_checkpoint_leaf(str(tmp_path), seed=1)
    assert ckpt.latest_step(str(tmp_path)) == 5   # still looks committed
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), 5, tree)


# --------------------------------------------------------------------------
# failed-save-I/O -> retry + async propagation
# --------------------------------------------------------------------------


def test_save_retry_rides_through_transient_io(tmp_path):
    tr = _mk("scan", "baseline", checkpoint_dir=str(tmp_path / "ckpt"),
             epochs=1)
    tr.run(1)
    with chaos.failing_leaf_writes(fail=1):
        path = tr.save_checkpoint()
    assert path is not None
    restored = _mk("scan", "baseline", checkpoint_dir=str(tmp_path / "ckpt"),
                   epochs=1)
    assert restored.restore_latest()


def test_save_failure_surfaces_when_disk_stays_dead(tmp_path):
    tr = _mk("scan", "baseline", checkpoint_dir=str(tmp_path / "ckpt"),
             epochs=1)
    tr.run(1)
    with chaos.failing_leaf_writes(fail=-1):
        with pytest.raises(OSError):
            tr.save_checkpoint()


def test_async_save_failure_surfaces_in_run(tmp_path):
    """An async save that dies on the worker thread must fail the run at
    the next checkpoint boundary — never silently report success."""
    tr = _mk("scan", "baseline", checkpoint_dir=str(tmp_path / "ckpt"),
             epochs=2, async_checkpoint=True)
    with chaos.failing_leaf_writes(fail=-1):
        with pytest.raises(OSError):
            tr.run(2)


def test_async_checkpoint_trainer_roundtrip(tmp_path):
    """Healthy async checkpointing: saves land, GC runs after confirmation,
    and a restore resumes from the final epoch."""
    cdir = str(tmp_path / "ckpt")
    tr = _mk("scan", "kakurenbo", checkpoint_dir=cdir,
             async_checkpoint=True)
    tr.run(3)
    assert tr._pending_save is None       # run() joined the trailing save
    assert ckpt.latest_step(cdir) == 3
    tr2 = _mk("scan", "kakurenbo", checkpoint_dir=cdir)
    assert tr2.restore_latest()
    assert tr2.epoch == 3


# --------------------------------------------------------------------------
# slow-shard -> straggler mitigation in the epoch loop
# --------------------------------------------------------------------------


def test_slow_shard_triggers_rebalance(caplog):
    """A persistently slow simulated worker must be flagged from its first
    recorded epoch and shed rows into the next epoch's plan — while the
    epoch still trains every visible sample exactly once."""
    # 512 samples = 2 full (workers x batch) chunks per epoch, so the
    # rebalance actually moves rows instead of degenerating to the tail.
    tr = _mk("scan", "baseline", num_samples=512, straggler_mitigation=True,
             straggler_workers=4)
    tr.shard_latency_fn = chaos.SlowShard(world_size=4, rank=1, factor=5.0)
    with caplog.at_level(logging.WARNING, logger="repro.train"):
        hist = tr.run(3)
    assert list(tr._straggler.stragglers()) == [False, True, False, False]
    assert any("straggler mitigation" in m for m in caplog.messages)
    # rebalancing reorders the plan, it never drops or duplicates work
    ref = _mk("scan", "baseline", num_samples=512)
    href = ref.run(3)
    assert ([h.fwd_samples for h in hist] == [h.fwd_samples for h in href])


def test_straggler_mitigation_uniform_latency_is_bit_exact():
    """With no skew the monitor never flags and the mitigation path must
    be invisible: bit-identical params to the unmonitored trainer."""
    tr_mon = _mk("scan", "kakurenbo", straggler_mitigation=True,
                 straggler_workers=4)
    tr_ref = _mk("scan", "kakurenbo")
    tr_mon.run(3)
    tr_ref.run(3)
    _assert_state_equal(tr_ref, tr_mon, "uniform-latency")
