"""Differential parity harness over every hidden-selection implementation.

One parametrized suite drives ``sort`` (paper O(N log N)), ``histogram``
(jnp O(N) CDF) and ``histogram_pallas`` (Pallas kernels, interpret mode on
CPU CI) through the same states and asserts they agree:

  * hidden counts match within the *documented* slack — the population of
    the boundary histogram bin(s) — and honour the F ceiling,
  * never-seen samples are never hidden,
  * the move-back rule is applied identically (mask(mb) == mask(no-mb) &
    confident-correct) by every method,
  * DropTop hides the highest-loss tail on every method (regression for the
    silently-ignored ``drop_top_fraction`` on the histogram path),
  * the two histogram implementations are BIT-identical (same binning
    formula, exact integer counts), including degenerate inputs:
    all-invalid state, constant loss (lo == hi), N not divisible by the
    kernel block size, and F in {0, large}.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    HIST_BINS, SELECTION_METHODS, init_sample_state, scatter_observations,
    select_hidden,
)

HIST_METHODS = ("histogram", "histogram_pallas")
TAU = 0.7

# (name, N) — N=3000 is deliberately not a multiple of the kernels'
# 2048-sample block; N=8 exercises tiny inputs.
CASES = {
    "exp": 1000,
    "uniform": 3000,
    "constant": 256,     # lo == hi: every sample lands in one bin
    "two_level": 512,    # exactly two populated bins
    "tiny": 8,
}


def _make_state(case: str, n: int, eligible: str = "all"):
    """eligible: 'all' | 'mixed' (random PA/PC) | 'none' (never observed)."""
    r = np.random.default_rng(hash(case) % (2**31))
    if case == "exp":
        losses = r.exponential(1.0, n).astype(np.float32)
    elif case == "uniform":
        losses = r.uniform(0.0, 10.0, n).astype(np.float32)
    elif case == "constant":
        losses = np.full(n, 3.5, np.float32)
    elif case == "two_level":
        losses = np.where(np.arange(n) % 2 == 0, 1.0, 2.0).astype(np.float32)
    elif case == "tiny":
        losses = np.linspace(0, 1, n).astype(np.float32)
    else:
        raise ValueError(case)
    s = init_sample_state(n)
    if eligible == "none":
        return s, losses
    if eligible == "all":
        pa = np.ones(n, bool)
        pc = np.ones(n, np.float32)
    else:
        pa = r.random(n) < 0.6
        pc = r.random(n).astype(np.float32)
    s = scatter_observations(s, jnp.arange(n), jnp.asarray(losses),
                             jnp.asarray(pa), jnp.asarray(pc), 0)
    return s, losses


def _boundary_slack(losses: np.ndarray, frac: float, top: bool = False,
                    bins: int = HIST_BINS) -> int:
    """The documented count slack of the histogram methods vs sort: the CDF
    walk cannot split the boundary bin, so counts may differ by up to that
    bin's population (a 3-bin window absorbs f32-vs-f64 edge rounding)."""
    lo, hi = float(losses.min()), float(losses.max())
    span = max(hi - lo, 1e-12)
    idx = np.clip(((losses - lo) / span * bins).astype(np.int64), 0, bins - 1)
    hist = np.bincount(idx, minlength=bins)
    k = int(np.floor(frac * len(losses)))
    cdf = np.cumsum(hist[::-1] if top else hist)
    b = int(np.clip(np.searchsorted(cdf, k, side="left"), 0, bins - 1))
    if top:
        b = bins - 1 - b
    return int(hist[max(b - 1, 0): b + 2].sum())


def _hide(state, frac, method, **kw):
    return np.asarray(select_hidden(state, frac, method=method, **kw))


# ---------------------------------------------------------------------------
# Cross-method agreement
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", sorted(CASES))
@pytest.mark.parametrize("frac", [0.0, 0.3, 0.7])
def test_methods_agree_on_hidden_count(case, frac):
    n = CASES[case]
    s, losses = _make_state(case, n, eligible="all")
    counts = {m: int(_hide(s, frac, m).sum()) for m in SELECTION_METHODS}
    slack = _boundary_slack(losses, frac)
    for m in HIST_METHODS:
        assert abs(counts[m] - counts["sort"]) <= slack, (case, frac, counts)


@pytest.mark.parametrize("case", sorted(CASES))
@pytest.mark.parametrize("frac", [0.0, 0.3, 0.7])
def test_histogram_pallas_bit_identical_to_histogram(case, frac):
    """The kernel path shares the threshold math with the jnp path, so the
    masks must be equal element-for-element — no tolerance."""
    s, _ = _make_state(case, CASES[case], eligible="mixed")
    np.testing.assert_array_equal(_hide(s, frac, "histogram"),
                                  _hide(s, frac, "histogram_pallas"))


# ---------------------------------------------------------------------------
# Shared invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", SELECTION_METHODS)
@pytest.mark.parametrize("case", sorted(CASES))
def test_f_ceiling(method, case):
    n = CASES[case]
    frac = 0.4
    s, losses = _make_state(case, n, eligible="all")
    limit = int(np.floor(frac * n))
    slack = 0 if method == "sort" else _boundary_slack(losses, frac)
    assert _hide(s, frac, method).sum() <= limit + slack


@pytest.mark.parametrize("method", SELECTION_METHODS)
def test_never_seen_never_hidden(method):
    s, _ = _make_state("exp", 1000, eligible="none")
    assert _hide(s, 0.5, method).sum() == 0
    # partially observed: the unobserved half must stay visible
    n = 1000
    r = np.random.default_rng(3)
    seen_idx = np.sort(r.choice(n, n // 2, replace=False))
    s = init_sample_state(n)
    s = scatter_observations(
        s, jnp.asarray(seen_idx),
        jnp.asarray(r.exponential(1.0, n // 2), jnp.float32),
        jnp.ones(n // 2, bool), jnp.ones(n // 2, jnp.float32), 0)
    hidden = _hide(s, 0.5, method)
    unseen = np.ones(n, bool)
    unseen[seen_idx] = False
    assert not hidden[unseen].any()


@pytest.mark.parametrize("method", SELECTION_METHODS)
@pytest.mark.parametrize("case", ["exp", "uniform", "tiny"])
def test_moveback_applied_identically(method, case):
    """mask(moveback) == mask(no-moveback) & confident-correct, for every
    method: move-back is a pure eligibility filter on the same candidates."""
    n = CASES[case]
    s, _ = _make_state(case, n, eligible="mixed")
    cc = (np.asarray(s.pa) & (np.asarray(s.pc) >= TAU)
          & (np.asarray(s.seen) >= 0))
    h_mb = _hide(s, 0.5, method, tau=TAU, moveback=True)
    h_free = _hide(s, 0.5, method, tau=TAU, moveback=False)
    np.testing.assert_array_equal(h_mb, h_free & cc)
    assert np.all(cc[h_mb])  # hidden => confident-correct


# ---------------------------------------------------------------------------
# DropTop (regression: the histogram path used to ignore drop_top_fraction)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", SELECTION_METHODS)
def test_droptop_hides_highest_loss_tail(method):
    n = 1024
    s, losses = _make_state("uniform", n, eligible="all")
    frac, top = 0.2, 0.05
    h = _hide(s, frac, method, drop_top_fraction=top)
    h_plain = _hide(s, frac, method)
    num_top = int(np.floor(top * n))
    slack = 0 if method == "sort" else _boundary_slack(losses, top, top=True)
    extra = int(h.sum()) - int(h_plain.sum())
    assert abs(extra - num_top) <= slack
    # the extra hidden samples are exactly a top-loss tail
    tail = h & ~h_plain
    if tail.any():
        assert losses[tail].min() >= np.partition(
            losses, n - num_top - slack - 1)[n - num_top - slack - 1]
    assert h[np.argmax(losses)]  # the hardest sample is dropped


@pytest.mark.parametrize("frac", [0.0, 0.2])
def test_droptop_methods_agree_with_never_seen(frac):
    """Regression: never-seen sentinel losses must not occupy sort's
    top-rank window — with half the dataset unobserved, all methods still
    drop ~the same number of *seen* top-loss samples."""
    n = 1000
    r = np.random.default_rng(11)
    losses = r.uniform(0, 1, n).astype(np.float32)
    seen_idx = np.sort(r.choice(n, n // 2, replace=False))
    s = init_sample_state(n)
    s = scatter_observations(
        s, jnp.asarray(seen_idx), jnp.asarray(losses[seen_idx]),
        jnp.ones(n // 2, bool), jnp.ones(n // 2, jnp.float32), 0)
    counts = {m: int(_hide(s, frac, m, drop_top_fraction=0.1).sum())
              for m in SELECTION_METHODS}
    # both tails carry boundary-bin slack; fractions are relative to the
    # 500 *seen* losses the histogram actually spans (0.1/frac of N=1000)
    seen_losses = losses[seen_idx]
    slack = (_boundary_slack(seen_losses, 0.2, top=True)
             + _boundary_slack(seen_losses, 2 * frac, top=False))
    for m in HIST_METHODS:
        assert abs(counts[m] - counts["sort"]) <= slack, counts
    # sort actually drops a top tail (used to drop ~0: the window was
    # filled by never-seen sentinels and then masked away)
    assert counts["sort"] >= int(0.1 * n) - slack


@pytest.mark.parametrize("method", SELECTION_METHODS)
def test_droptop_exempts_never_seen(method):
    """DropTop ignores move-back but must not hide never-seen samples."""
    n = 512
    r = np.random.default_rng(7)
    losses = r.uniform(0, 1, n).astype(np.float32)
    top_half = np.argsort(losses)[n // 2:]
    seen_idx = np.setdiff1d(np.arange(n), top_half[:50])  # 50 top unseen
    s = init_sample_state(n)
    s = scatter_observations(
        s, jnp.asarray(seen_idx), jnp.asarray(losses[seen_idx]),
        jnp.ones(len(seen_idx), bool), jnp.ones(len(seen_idx), jnp.float32), 0)
    h = _hide(s, 0.0, method, drop_top_fraction=0.3)
    assert not h[top_half[:50]].any()
    assert h.sum() > 0  # but seen top-loss samples are dropped
