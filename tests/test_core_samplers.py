"""Unit tests for the KAKURENBO orchestrator and the baseline samplers."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (
    ForgetConfig, ForgetSampler, ISWRSampler, KakurenboConfig,
    KakurenboSampler, SBConfig, SelectiveBackprop, GradMatchConfig,
    GradMatchSampler,
)


def _observe_all(sampler, n, losses, pa, pc, epoch):
    sampler.observe(np.arange(n), jnp.asarray(losses, jnp.float32),
                    jnp.asarray(pa), jnp.asarray(pc, jnp.float32), epoch)


def test_kakurenbo_epoch_cycle():
    n = 200
    ks = KakurenboSampler(n, KakurenboConfig(max_fraction=0.3,
                                             fraction_milestones=(0, 5, 8, 10)))
    plan0 = ks.begin_epoch(0)
    assert len(plan0.hidden_indices) == 0          # nothing observed yet
    losses = np.linspace(0, 1, n)
    _observe_all(ks, n, losses, np.ones(n, bool), np.full(n, 0.9), 0)
    plan1 = ks.begin_epoch(1)
    assert 0 < len(plan1.hidden_indices) <= int(0.3 * n)
    # hidden are the lowest-loss samples
    assert losses[plan1.hidden_indices].max() <= losses[
        plan1.visible_indices].min() + 1e-9
    # visible + hidden partition the dataset
    assert len(plan1.visible_indices) + len(plan1.hidden_indices) == n
    np.testing.assert_allclose(plan1.lr_scale,
                               1.0 / (1.0 - plan1.hidden_fraction), rtol=1e-6)


def test_kakurenbo_moveback_blocks_low_confidence():
    n = 100
    ks = KakurenboSampler(n, KakurenboConfig(max_fraction=0.5, tau=0.7))
    losses = np.linspace(0, 1, n)
    pc = np.where(np.arange(n) % 2 == 0, 0.9, 0.1)  # odd samples low-PC
    _observe_all(ks, n, losses, np.ones(n, bool), pc, 0)
    plan = ks.begin_epoch(1)
    assert np.all(plan.hidden_indices % 2 == 0)


def test_kakurenbo_component_toggles():
    n = 100
    cfg = KakurenboConfig(max_fraction=0.4, moveback=False, adjust_lr=False,
                          reduce_fraction=False)
    ks = KakurenboSampler(n, cfg)
    losses = np.linspace(0, 1, n)
    _observe_all(ks, n, losses, np.zeros(n, bool), np.zeros(n), 0)
    plan = ks.begin_epoch(1)
    # without move-back, low-loss samples are hidden even if never confident
    assert len(plan.hidden_indices) == 40
    assert plan.lr_scale == 1.0


def test_droptop_hides_highest_loss():
    n = 100
    ks = KakurenboSampler(n, KakurenboConfig(max_fraction=0.2,
                                             drop_top_fraction=0.05))
    losses = np.linspace(0, 1, n)
    _observe_all(ks, n, losses, np.ones(n, bool), np.full(n, 0.99), 0)
    plan = ks.begin_epoch(1)
    hidden = set(plan.hidden_indices.tolist())
    assert {95, 96, 97, 98, 99} <= hidden  # DropTop tail


def test_iswr_prefers_high_loss():
    n = 1000
    s = ISWRSampler(n, seed=0)
    losses = np.zeros(n)
    losses[:100] = 10.0  # 100 high-loss samples
    _observe_all(s, n, losses, np.ones(n, bool), np.ones(n), 0)
    idx = s.begin_epoch(1)
    assert len(idx) == n  # with replacement, same epoch size
    frac_high = np.mean(idx < 100)
    assert frac_high > 0.5  # 10% of samples get >50% of draws


def test_forget_prunes_unforgettable_and_restarts():
    n = 100
    s = ForgetSampler(n, ForgetConfig(fraction=0.3, warmup_epochs=2))
    # samples 0..49: always correct (unforgettable); 50..99 flip each epoch
    for e in range(2):
        pa = np.ones(n, bool)
        pa[50:] = e % 2 == 0
        _observe_all(s, n, np.ones(n), pa, np.ones(n), e)
        s.begin_epoch(e)
    idx = s.begin_epoch(2)
    assert s.should_restart
    assert len(idx) == 70
    pruned = set(range(n)) - set(idx.tolist())
    assert all(i < 50 for i in pruned)  # only unforgettable samples pruned


def test_selective_backprop_keeps_high_loss():
    sb = SelectiveBackprop(SBConfig(beta=1.0), seed=0)
    r = np.random.default_rng(0)
    for _ in range(10):  # warm the history
        sb.select(r.random(64).astype(np.float32))
    low = sb.select(np.full(64, 0.001, np.float32)).mean()
    high = sb.select(np.full(64, 0.999, np.float32)).mean()
    assert high > low


def test_gradmatch_selects_subset_with_weights():
    n, c = 120, 3
    r = np.random.default_rng(0)
    labels = np.arange(n) % c
    feats = r.normal(size=(n, 8)).astype(np.float32)
    gm = GradMatchSampler(n, c, GradMatchConfig(fraction=0.5, interval=1))
    assert gm.maybe_reselect(0, feats, labels)
    assert len(gm.subset) <= int(0.5 * n) + c
    assert np.all(gm.weights >= 0)
    idx = gm.begin_epoch()
    assert set(idx.tolist()) == set(gm.subset.tolist())
