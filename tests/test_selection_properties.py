"""Property tests on KAKURENBO's selection invariants.

Runs under hypothesis when it is installed; otherwise a minimal seeded
fallback shim replays the same ``@given`` strategies over a fixed set of
deterministic RNG streams, so the invariants are always *exercised* — never
skipped — on machines without hypothesis (this container's tier-1 run).
"""
from __future__ import annotations


import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401  (kept for parity with the other suites)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # seeded fallback: same API surface, fixed seeds
    HAVE_HYPOTHESIS = False
    FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample  # np.random.Generator -> value

    class _St:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda r: int(r.integers(lo, hi + 1)))

        @staticmethod
        def floats(lo, hi):
            return _Strategy(lambda r: float(r.uniform(lo, hi)))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda r: seq[int(r.integers(len(seq)))])

        @staticmethod
        def composite(fn):
            def make(*args, **kw):
                return _Strategy(
                    lambda r: fn(lambda s: s.sample(r), *args, **kw))
            return make

    st = _St()

    class settings:  # noqa: N801  (mirrors the hypothesis name)
        @staticmethod
        def register_profile(*a, **k):
            pass

        @staticmethod
        def load_profile(*a, **k):
            pass

    def given(*strats):
        def deco(test):
            # NB: not functools.wraps — copying the signature would make
            # pytest resolve the original parameters as fixtures.
            def run():
                for seed in range(FALLBACK_EXAMPLES):
                    r = np.random.default_rng(seed)
                    test(*(s.sample(r) for s in strats))
            run.__name__ = test.__name__
            run.__doc__ = test.__doc__
            return run
        return deco


from repro.core import (
    FractionSchedule, init_sample_state, kakurenbo_lr, scatter_observations,
    select_hidden,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _observed_state(losses, pa, pc):
    n = len(losses)
    s = init_sample_state(n)
    return scatter_observations(
        s, jnp.arange(n), jnp.asarray(losses, jnp.float32),
        jnp.asarray(pa), jnp.asarray(pc, jnp.float32), 0)


@st.composite
def sample_states(draw):
    n = draw(st.integers(8, 200))
    r = np.random.default_rng(draw(st.integers(0, 2**31)))
    losses = r.exponential(1.0, n).astype(np.float32)
    pa = r.random(n) < draw(st.floats(0.0, 1.0))
    pc = r.random(n).astype(np.float32)
    return losses, pa, pc


@given(sample_states(), st.floats(0.0, 0.9),
       st.sampled_from(["sort", "histogram", "histogram_pallas"]))
def test_hidden_count_bounded(state_args, frac, method):
    """|hidden| <= F*N + slack; hidden implies confident-correct; never-seen
    samples are never hidden."""
    losses, pa, pc = state_args
    n = len(losses)
    s = _observed_state(losses, pa, pc)
    hidden = np.asarray(select_hidden(s, frac, method=method, tau=0.7))
    limit = int(np.floor(frac * n))
    slack = 0 if method == "sort" else max(4, n // 64)  # histogram bin slack
    assert hidden.sum() <= limit + slack
    # move-back rule: hidden => PA and PC >= tau
    assert np.all(pa[hidden])
    assert np.all(pc[hidden] >= 0.7)


@given(sample_states(), st.floats(0.05, 0.9))
def test_sort_hides_lowest_losses(state_args, frac):
    """Among confident-correct samples, the hidden ones have losses <= every
    visible confident-correct sample outside the candidate set."""
    losses, pa, pc = state_args
    pa = np.ones_like(pa)  # all eligible -> pure loss ranking
    pc = np.ones_like(pc)
    s = _observed_state(losses, pa, pc)
    hidden = np.asarray(select_hidden(s, frac, method="sort"))
    k = int(np.floor(frac * len(losses)))
    if k == 0:
        assert hidden.sum() == 0
        return
    assert hidden.sum() == k
    thresh = np.sort(losses)[k - 1]
    assert np.all(losses[hidden] <= thresh + 1e-6)


@given(sample_states(), st.floats(0.05, 0.9))
def test_histogram_approximates_sort(state_args, frac):
    losses, pa, pc = state_args
    pa = np.ones_like(pa)
    pc = np.ones_like(pc)
    s = _observed_state(losses, pa, pc)
    h_sort = np.asarray(select_hidden(s, frac, method="sort"))
    h_hist = np.asarray(select_hidden(s, frac, method="histogram"))
    n = len(losses)
    # counts agree within one histogram bin's population
    assert abs(int(h_sort.sum()) - int(h_hist.sum())) <= max(4, n // 16)


@given(st.integers(0, 300))
def test_fraction_schedule_monotone_nonincreasing(epoch):
    fs = FractionSchedule(0.3, (1.0, 0.8, 0.6, 0.4), (0, 30, 60, 80))
    f_now = float(fs(epoch))
    f_next = float(fs(epoch + 1))
    assert 0.0 <= f_next <= f_now <= 0.3 + 1e-6


@given(st.floats(0.0, 0.9), st.floats(1e-4, 1.0))
def test_lr_adjustment_equation8(frac, base):
    lr = float(kakurenbo_lr(jnp.float32(base), frac))
    assert lr >= base * (1 - 1e-6)  # f32 rounding slack
    np.testing.assert_allclose(lr, base / (1 - min(frac, 0.95)), rtol=1e-5)


@given(sample_states())
def test_never_seen_never_hidden(state_args):
    losses, pa, pc = state_args
    n = len(losses)
    s = init_sample_state(n)  # nothing observed
    for method in ("sort", "histogram", "histogram_pallas"):
        hidden = np.asarray(select_hidden(s, 0.5, method=method))
        assert hidden.sum() == 0, method


@given(sample_states(), st.integers(0, 2**31))
def test_selection_permutation_equivariant(state_args, seed):
    """Permuting samples permutes the hidden mask identically (no positional
    bias in selection)."""
    losses, pa, pc = state_args
    # make losses unique so ranking is deterministic under permutation
    losses = losses + np.arange(len(losses), dtype=np.float32) * 1e-6
    perm = np.random.default_rng(seed).permutation(len(losses))
    s1 = _observed_state(losses, pa, pc)
    s2 = _observed_state(losses[perm], pa[perm], pc[perm])
    h1 = np.asarray(select_hidden(s1, 0.4, method="sort"))
    h2 = np.asarray(select_hidden(s2, 0.4, method="sort"))
    assert np.array_equal(h1[perm], h2)
