"""Integration: trainer + checkpoint/restart, failure injection, elasticity,
straggler mitigation, optimizers, gradient compression."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.core import KakurenboConfig, LRSchedule
from repro.data import SyntheticClassification, worker_slice
from repro.models import cnn
from repro.optim import make_optimizer
from repro.train import Trainer, TrainConfig
from repro.train.fault import StragglerMonitor, rescale_plan, run_with_restarts

CFG_MODEL = cnn.CNNConfig(image_size=8, widths=(8,), hidden=16)


def _mk_trainer(tmp_path, strategy="kakurenbo", epochs=4, ds=None, seed=0,
                fused=True, strategy_obj=None):
    ds = ds or SyntheticClassification(num_samples=256, image_size=8, seed=0)

    def init_params(rng):
        return cnn.init(rng, CFG_MODEL)

    def loss_fn(params, batch):
        logits = cnn.forward(params, CFG_MODEL, batch["images"])
        loss, pa, pc = cnn.per_sample_metrics(logits, batch["labels"])
        w = batch.get("weight")
        scalar = jnp.mean(loss * w) if w is not None else jnp.mean(loss)
        return scalar, (loss, pa, pc)

    tc = TrainConfig(
        epochs=epochs, batch_size=64, strategy=strategy,
        lr=LRSchedule(0.05, "cosine", epochs, 1),
        kakurenbo=KakurenboConfig(max_fraction=0.3,
                                  fraction_milestones=(0, 2, 3, 4)),
        checkpoint_dir=str(tmp_path / "ckpt"), checkpoint_every=1, seed=seed,
        fused_observe=fused)
    return Trainer(tc, init_params, loss_fn, ds, ds.test_split(64),
                   strategy=strategy_obj)


def test_checkpoint_restart_bit_exact(tmp_path):
    """Crash at epoch 2, restart from checkpoint -> same final params as an
    uninterrupted run (bit-exact, incl. KAKURENBO sampler state)."""
    tr_ref = _mk_trainer(tmp_path / "ref")
    tr_ref.run(4)

    made = []

    def make():
        t = _mk_trainer(tmp_path / "crash")
        made.append(t)
        return t

    with pytest.raises(RuntimeError):
        make().run(4, fail_at_epoch=2)
    tr2, restarts = run_with_restarts(make, 4)
    leaves_ref = jax.tree.leaves(tr_ref.params)
    leaves_re = jax.tree.leaves(tr2.params)
    for a, b in zip(leaves_ref, leaves_re):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # sampler state also restored + advanced identically
    np.testing.assert_array_equal(np.asarray(tr_ref.sampler.state.loss),
                                  np.asarray(tr2.sampler.state.loss))


def test_fused_observe_bit_identical_to_host_path(tmp_path):
    """The device-resident engine (observe scatter fused into the jitted
    train step, one SampleState host sync per epoch) must reproduce the
    per-batch host observe() path bit-for-bit over a seeded 3-epoch run:
    same hidden sets, same lagging state, same params."""
    tr_fused = _mk_trainer(tmp_path / "fused", epochs=3)
    tr_host = _mk_trainer(tmp_path / "host", epochs=3, fused=False)
    hist_fused = tr_fused.run(3)
    hist_host = tr_host.run(3)

    for a, b in zip(jax.tree.leaves(tr_fused.params),
                    jax.tree.leaves(tr_host.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for field in ("hidden", "loss", "pa", "pc", "seen"):
        np.testing.assert_array_equal(
            np.asarray(getattr(tr_fused.sampler.state, field)),
            np.asarray(getattr(tr_host.sampler.state, field)), err_msg=field)

    # ...and the engine's point: <= 1 SampleState host sync per epoch in the
    # fused plan+batch loop, vs 1 + num_batches on the legacy path.
    assert all(s.host_syncs == 1 for s in hist_fused)
    assert all(s.host_syncs > 1 for s in hist_host)
    # identical work accounting either way
    assert ([(s.fwd_samples, s.bwd_samples) for s in hist_fused]
            == [(s.fwd_samples, s.bwd_samples) for s in hist_host])


def test_resume_preserves_epoch_permutation(tmp_path):
    """A kakurenbo run interrupted mid-training must resume with the exact
    epoch permutation and hidden set the uninterrupted run would have drawn:
    the jitted plan step's device RNG key is checkpointed bit-exactly."""
    tr_ref = _mk_trainer(tmp_path)
    tr_ref.run(2)  # checkpoints at every epoch

    tr_res = _mk_trainer(tmp_path, seed=99)  # wrong seed: restore must win
    assert tr_res.restore_latest()
    assert tr_res.epoch == 2

    plan_ref = tr_ref.strategy.plan(2)
    plan_res = tr_res.strategy.plan(2)
    np.testing.assert_array_equal(plan_ref.visible_indices,
                                  plan_res.visible_indices)
    np.testing.assert_array_equal(plan_ref.hidden_indices,
                                  plan_res.hidden_indices)
    assert plan_ref.lr_scale == plan_res.lr_scale
    np.testing.assert_array_equal(np.asarray(tr_ref.sampler.state.hidden),
                                  np.asarray(tr_res.sampler.state.hidden))


def test_backward_work_accounting(tmp_path):
    """The step reports its own backward count as a device scalar: full
    batches for plain strategies, the fused select's surviving subset for
    SB — and the paper's work accounting must never silently zero out."""
    ds = SyntheticClassification(num_samples=256, image_size=8, seed=0)
    stats = _mk_trainer(tmp_path / "base", strategy="baseline", ds=ds,
                        epochs=1).run_epoch(0)
    assert stats.bwd_samples == stats.fwd_samples == 256
    stats_sb = _mk_trainer(tmp_path / "sb", strategy="sb", ds=ds,
                           epochs=1).run_epoch(0)
    # bootstrap trains the first batch fully; later batches drop samples
    assert 0 < stats_sb.bwd_samples < stats_sb.fwd_samples == 256


def test_checkpoint_integrity_detects_corruption(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3))}}
    path = ckpt.save(str(tmp_path), 1, tree)
    # corrupt one leaf
    import numpy as _np
    f = path + "/leaf_00000.npy"
    arr = _np.load(f)
    arr[0] = 999.0
    _np.save(f, arr)
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), 1, tree)


def test_checkpoint_uncommitted_ignored(tmp_path):
    tree = {"a": jnp.arange(4.0)}
    ckpt.save(str(tmp_path), 1, tree)
    # a partially-written step dir without COMMITTED must be invisible
    import os
    os.makedirs(str(tmp_path / "step_0000000002"))
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_async_checkpoint(tmp_path):
    tree = {"a": jnp.arange(16.0)}
    t = ckpt.save_async(str(tmp_path), 3, tree)
    t.join()
    restored, _ = ckpt.restore(str(tmp_path), 3, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_worker_slice_partitions_epoch():
    idx = np.arange(1000)
    np.random.default_rng(0).shuffle(idx)
    views = [worker_slice(idx, 4, r, 8) for r in range(4)]
    allv = np.concatenate(views)
    assert len(allv) == (1000 // 32) * 32
    assert len(np.unique(allv)) == len(allv)  # disjoint


def test_elastic_rescale_covers_same_samples():
    """Rescaling 4 -> 8 workers re-partitions the same epoch permutation."""
    idx = np.arange(512)
    p4 = rescale_plan(idx, 4, 16)
    p8 = rescale_plan(idx, 8, 8)
    s4 = set(np.concatenate(p4.per_worker).tolist())
    s8 = set(np.concatenate(p8.per_worker).tolist())
    assert s4 == s8 == set(range(512))


def test_straggler_rebalance():
    mon = StragglerMonitor(4)
    for _ in range(5):
        for r, t in enumerate([1.0, 1.0, 1.0, 3.0]):
            mon.record(r, t)
    assert list(mon.stragglers()) == [False, False, False, True]
    per_worker = [np.arange(i * 100, (i + 1) * 100) for i in range(4)]
    out = mon.rebalance(per_worker, shed_fraction=0.25)
    assert len(out[3]) == 75
    assert sum(len(w) for w in out) == 400


@pytest.mark.parametrize("name,hp", [
    ("sgd", {"momentum": 0.9, "nesterov": True}),
    ("adamw", {}),
    ("rmsprop", {}),
    ("adafactor", {}),
])
def test_optimizers_reduce_quadratic(name, hp):
    """Every optimizer minimizes a quadratic."""
    opt = make_optimizer(name, **hp)
    params = {"w": jnp.asarray([5.0, -3.0]), "b": jnp.asarray([[1.0, 2.0],
                                                               [3.0, 4.0]])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.float32(0.05))
    assert float(loss(params)) < 0.5 * l0


def test_gradient_compression_error_feedback():
    from repro.dist.compression import compress_grads, init_error_feedback
    r = np.random.default_rng(0)
    g = {"w": jnp.asarray(r.normal(size=(64,)), jnp.float32)}
    ef = init_error_feedback(g)
    # accumulated compressed gradients track the true sum (error feedback)
    acc_true = np.zeros(64)
    acc_comp = np.zeros(64)
    for _ in range(50):
        gi = {"w": jnp.asarray(r.normal(size=(64,)), jnp.float32)}
        cg, ef = compress_grads(gi, ef)
        acc_true += np.asarray(gi["w"])
        acc_comp += np.asarray(cg["w"])
    # residual stays bounded (= current error feedback, one-step quantization)
    assert np.max(np.abs(acc_true - acc_comp)) < 0.2


def test_grad_compression_training_converges(tmp_path):
    ds = SyntheticClassification(num_samples=128, image_size=8, seed=0)
    tr = _mk_trainer(tmp_path, strategy="baseline", epochs=3, ds=ds)
    tr.cfg = dataclasses.replace(tr.cfg, grad_compression=True)
    from repro.dist.compression import init_error_feedback
    tr.ef_state = init_error_feedback(tr.params)
    tr._jit_steps()
    hist = tr.run(3)
    assert hist[-1].train_loss < hist[0].train_loss


# --------------------------------------------------------------------------
# checkpoint fallback chain + save resilience (unit level; trainer-level
# integration lives in tests/test_chaos.py)
# --------------------------------------------------------------------------


def _corrupt_leaf(directory, step):
    f = f"{directory}/step_{step:010d}/leaf_00000.npy"
    arr = np.load(f)
    arr = arr + 1  # payload change under an intact manifest -> CRC mismatch
    np.save(f, arr)


def test_restore_latest_falls_back_and_quarantines(tmp_path):
    tree = {"a": jnp.arange(8.0)}
    ckpt.save(str(tmp_path), 1, {"a": jnp.arange(8.0) * 1})
    ckpt.save(str(tmp_path), 2, {"a": jnp.arange(8.0) * 2})
    _corrupt_leaf(str(tmp_path), 2)
    restored, _, step = ckpt.restore_latest(str(tmp_path), tree)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(8.0))
    # the corrupt dir left the committed chain but its bytes survive
    assert ckpt.latest_step(str(tmp_path)) == 1
    assert (tmp_path / "corrupt_step_0000000002").is_dir()


def test_restore_latest_reraises_when_all_corrupt(tmp_path):
    tree = {"a": jnp.arange(8.0)}
    ckpt.save(str(tmp_path), 1, tree)
    _corrupt_leaf(str(tmp_path), 1)
    with pytest.raises(IOError):
        ckpt.restore_latest(str(tmp_path), tree)


def test_restore_latest_structure_mismatch_no_quarantine(tmp_path):
    """A valid checkpoint from a different config must fall back but NOT be
    quarantined — the bytes are fine, the tree changed."""
    like = {"a": jnp.arange(8.0)}
    ckpt.save(str(tmp_path), 1, like)
    ckpt.save(str(tmp_path), 2, {"a": jnp.arange(8.0), "b": jnp.zeros(2)})
    _, _, step = ckpt.restore_latest(str(tmp_path), like)
    assert step == 1
    assert ckpt.latest_step(str(tmp_path)) == 2   # step 2 still committed


def test_save_retries_transient_oserror(tmp_path):
    from repro.train import chaos
    tree = {"a": jnp.arange(8.0), "b": jnp.ones(3)}
    sleeps = []
    with chaos.failing_leaf_writes(fail=1) as calls:
        path = ckpt.save(str(tmp_path), 1, tree, _sleep=sleeps.append)
    # attempt 1 died on leaf 0; attempt 2 rewrote both leaves from scratch
    assert calls["n"] == 3 and sleeps == [0.05]
    restored, _ = ckpt.restore(str(tmp_path), 1, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(8.0))
    assert path.endswith("step_0000000001")


def test_save_raises_after_retries_exhausted(tmp_path):
    from repro.train import chaos
    with chaos.failing_leaf_writes(fail=-1):
        with pytest.raises(OSError):
            ckpt.save(str(tmp_path), 1, {"a": jnp.arange(4.0)},
                      _sleep=lambda s: None)
    assert ckpt.latest_step(str(tmp_path)) is None   # nothing committed


def test_save_async_failure_propagates(tmp_path):
    from repro.train import chaos
    with chaos.failing_leaf_writes(fail=-1):
        h = ckpt.save_async(str(tmp_path), 1, {"a": jnp.arange(4.0)})
        assert isinstance(h.exception(), OSError)
        with pytest.raises(OSError):
            h.join()
    # a healthy handle returns the committed path from result()
    h = ckpt.save_async(str(tmp_path), 2, {"a": jnp.arange(4.0)})
    assert h.result().endswith("step_0000000002")
    assert h.done() and h.exception() is None


# --------------------------------------------------------------------------
# supervisor: classification, backoff, restart budget window
# --------------------------------------------------------------------------


class _ScriptedTrainer:
    """Supervisor-contract stub: attempt k advances to ``script[k][0]`` and
    raises ``script[k][1]`` (None = success).  ``state`` persists epoch +
    attempt count across rebuilds, standing in for the checkpoint dir."""

    def __init__(self, state, script):
        self.state = state
        self.script = script
        self.epoch = 0

    def restore_latest(self):
        self.epoch = self.state["epoch"]
        return self.epoch > 0

    def run(self, total_epochs):
        k = self.state["attempt"]
        self.state["attempt"] += 1
        to_epoch, exc = self.script[min(k, len(self.script) - 1)]
        self.epoch = max(self.epoch, to_epoch)
        self.state["epoch"] = self.epoch
        if exc is not None:
            raise exc


def _scripted(script):
    state = {"epoch": 0, "attempt": 0}
    return state, (lambda: _ScriptedTrainer(state, script))


def test_classify_failure_policy():
    from repro.train.fault import classify_failure
    from repro.train.guard import NonFiniteError
    from repro.train.chaos import ChaosError
    for exc in (OSError("disk"), RuntimeError("xla"), ValueError("decode"),
                EOFError(), ConnectionError(), NonFiniteError("nan"),
                ChaosError("injected"), IOError("crc")):
        assert classify_failure(exc) == "restartable", exc
    class Unknown(Exception):
        pass
    for exc in (TypeError(), AttributeError(), KeyError(), IndexError(),
                AssertionError(), NotImplementedError(), Unknown()):
        assert classify_failure(exc) == "fatal", exc


def test_run_with_restarts_fatal_not_retried():
    state, make = _scripted([(0, KeyError("bug"))])
    with pytest.raises(KeyError):
        run_with_restarts(make, 4, sleep_fn=lambda s: None)
    assert state["attempt"] == 1   # a programming bug never burns restarts


def test_run_with_restarts_backoff_escalates_while_stagnant():
    state, make = _scripted([(0, OSError()), (0, OSError()), (0, OSError()),
                             (4, None)])
    sleeps = []
    _, restarts = run_with_restarts(make, 4, max_restarts=5,
                                    sleep_fn=sleeps.append)
    assert restarts == 3
    assert sleeps == [0.5, 1.0, 2.0]   # base * factor**stagnant, no progress


def test_run_with_restarts_backoff_resets_on_progress():
    state, make = _scripted([(1, OSError()), (1, OSError()), (2, OSError()),
                             (4, None)])
    sleeps = []
    _, restarts = run_with_restarts(make, 4, sleep_fn=sleeps.append)
    assert restarts == 3
    # crash-with-progress sleeps 0 (skipped); only the stagnant retry waits
    assert sleeps == [0.5]


def test_run_with_restarts_budget_is_sliding_window():
    script = [(1, OSError()), (2, OSError()), (3, OSError()),
              (4, OSError()), (5, None)]
    # Without a window, the 3rd restart exceeds max_restarts=2.
    state, make = _scripted(script)
    with pytest.raises(OSError):
        run_with_restarts(make, 5, max_restarts=2, sleep_fn=lambda s: None)
    # With a 10s window and a clock ticking 6s per restart, old restarts
    # age out and the same run completes.
    state, make = _scripted(script)
    t = {"now": 0.0}
    def clock():
        t["now"] += 6.0
        return t["now"]
    _, restarts = run_with_restarts(make, 5, max_restarts=2,
                                    restart_window=10.0, clock=clock,
                                    sleep_fn=lambda s: None)
    assert restarts == 4
