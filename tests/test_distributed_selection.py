"""Distributed KAKURENBO selection: the shard_map histogram path (sample
state sharded over the data axes, O(bins) psum) must match single-device
selection. Also covers InfoBatch (new baseline)."""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.dist.sharding import shard_map_compat
from repro.core.state import init_sample_state, scatter_observations
from repro.core.selection import select_hidden_histogram, select_hidden

n = 4096
rng = np.random.default_rng(0)
losses = jnp.asarray(rng.exponential(1.0, n), jnp.float32)
pa = jnp.asarray(rng.random(n) < 0.8)
pc = jnp.asarray(rng.random(n), jnp.float32)
state = scatter_observations(init_sample_state(n), jnp.arange(n), losses, pa, pc, 0)

# single-device reference
ref = np.asarray(select_hidden(state, 0.3, method="histogram"))

mesh = jax.make_mesh((8,), ("data",))
sharded = jax.device_put(state, NamedSharding(mesh, P("data")))

def local_select(st):
    return select_hidden_histogram(st, 0.3, axis_names=("data",))

out = shard_map_compat(
    local_select, mesh=mesh, in_specs=(P("data"),), out_specs=P("data"),
    check_vma=False,
)(sharded)
got = np.asarray(out)
agree = (got == ref).mean()
print(f"agreement={agree:.4f} hidden_ref={ref.sum()} hidden_dist={got.sum()}")
assert agree > 0.999, agree
print("DIST_SELECT_OK")
"""


def test_shardmap_histogram_selection_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=300)
    assert "DIST_SELECT_OK" in res.stdout, res.stdout + res.stderr


def test_infobatch_prunes_and_rescales():
    import jax.numpy as jnp
    from repro.core import InfoBatchConfig, InfoBatchSampler

    n = 1000
    s = InfoBatchSampler(n, InfoBatchConfig(prune_ratio=0.5, anneal=0.9,
                                            total_epochs=10), seed=0)
    losses = np.linspace(0, 2, n)  # mean = 1.0
    s.observe(np.arange(n), jnp.asarray(losses, jnp.float32),
              jnp.ones(n, bool), jnp.ones(n, jnp.float32), 0)
    idx, pruned = s.begin_epoch(1)
    np.testing.assert_array_equal(pruned, np.setdiff1d(np.arange(n), idx))
    assert len(pruned) > 0
    assert np.all(losses[pruned] < 1.0)          # only below-mean pruned
    # kept below-mean samples are rescaled 1/(1-r) = 2.0
    kept_below = np.array([i for i in idx if losses[i] < 1.0])
    w = s.sample_weights(kept_below)
    np.testing.assert_allclose(w, 2.0)
    above = np.array([i for i in idx if losses[i] >= 1.0])
    np.testing.assert_allclose(s.sample_weights(above), 1.0)
    # annealing: final epochs train on everything
    idx9, pruned9 = s.begin_epoch(9)
    assert len(idx9) == n and len(pruned9) == 0


def test_infobatch_trainer_integration(tmp_path):
    import jax.numpy as jnp
    from repro.core import LRSchedule
    from repro.data import SyntheticClassification
    from repro.models import cnn
    from repro.train import Trainer, TrainConfig

    cfgm = cnn.CNNConfig(image_size=8, widths=(8,), hidden=16)
    ds = SyntheticClassification(num_samples=256, image_size=8, seed=0)

    def loss_fn(params, batch):
        logits = cnn.forward(params, cfgm, batch["images"])
        loss, pa, pc = cnn.per_sample_metrics(logits, batch["labels"])
        w = batch.get("weight")
        scalar = jnp.mean(loss * w) if w is not None else jnp.mean(loss)
        return scalar, (loss, pa, pc)

    tc = TrainConfig(epochs=4, batch_size=64, strategy="infobatch",
                     lr=LRSchedule(0.03, "cosine", 4, 1))
    tr = Trainer(tc, lambda r: cnn.init(r, cfgm), loss_fn, ds,
                 ds.test_split(64))
    hist = tr.run()
    assert hist[-1].train_loss < hist[0].train_loss
    # pruning actually shrinks the epoch index list once losses are observed
    # (bwd_samples stays batch-quantized because the pipeline pads)
    idx, _ = tr._epoch_indices(1)
    assert len(idx) < 256
