"""Distributed correctness: the pjit/shard_map path on a (data, model) mesh
must produce the same numbers as the single-device path.

Runs in a subprocess because the fake-device count must be fixed before jax
initializes (same mechanism as launch/dryrun.py).
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig
from repro.dist.sharding import ParallelCtx
from repro.models import build_model
from repro.launch.train import shardings_for

def check(cfg, tol=3e-3):
    rng = np.random.default_rng(0)
    B, S = 8, 32
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
             "mask": jnp.ones((B, S), bool)}
    # single device reference
    m0 = build_model(cfg)
    params = m0.init(jax.random.key(0))
    ref, (lv0, pa0, pc0) = m0.loss_and_metrics(params, batch)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, fsdp=True)
    m1 = build_model(cfg, ctx)
    pspecs = m1.param_specs(jnp.float32)
    pshard = shardings_for(mesh, pspecs)
    params_sh = jax.device_put(params, pshard)
    bshard = NamedSharding(mesh, P("data"))
    batch_sh = {k: jax.device_put(v, NamedSharding(mesh, P("data", *([None]*(v.ndim-1)))))
                for k, v in batch.items()}
    f = jax.jit(m1.loss_and_metrics, in_shardings=(pshard, jax.tree.map(lambda _: None, batch)))
    out, (lv1, pa1, pc1) = f(params_sh, batch_sh)
    err = abs(float(out) - float(ref))
    lv_err = float(jnp.max(jnp.abs(lv0 - lv1)))
    print(f"{cfg.name}: scalar_err={err:.2e} lv_err={lv_err:.2e}")
    assert err < tol, (cfg.name, err)
    assert lv_err < tol, (cfg.name, lv_err)

dense = ArchConfig("dense-d", "dense", 2, 64, 8, 4, 128, 256, head_dim=16, qk_norm=True)
moe = ArchConfig("moe-d", "moe", 2, 64, 8, 4, 0, 256, head_dim=16,
                 moe=MoEConfig(8, 2, 64, capacity_factor=8.0))
ssm = ArchConfig("ssm-d", "ssm", 2, 64, 0, 0, 0, 256, ssm=SSMConfig(16, 16, chunk=16))
check(dense)
check(ssm)
check(moe, tol=2e-2)  # capacity routing differs per data shard (T_local)
print("DISTRIBUTED_OK")
"""


@pytest.mark.slow
def test_sharded_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run([sys.executable, "-c", _SCRIPT], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), env=env,
        capture_output=True, text=True, timeout=560)
    assert "DISTRIBUTED_OK" in res.stdout, res.stdout + res.stderr
