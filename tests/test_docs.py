"""Docs lint (also wired as a dedicated CI step).

Two guarantees:

1. every relative markdown link in ``docs/*.md`` and ``README.md`` resolves
   to a real file — the docs map (architecture / paper_map /
   adding_a_strategy / benchmarks) must not rot as files move;
2. every ``@register_strategy`` name is documented in
   ``docs/paper_map.md`` — adding a strategy without documenting its paper
   role fails CI (see docs/adding_a_strategy.md).
"""
from __future__ import annotations

import re
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# [text](target) — excluding images and in-page anchors; external schemes
# are skipped below.
_LINK = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)#\s]+)(?:#[^)\s]*)?\)")


def _doc_files() -> list[Path]:
    files = sorted((ROOT / "docs").glob("*.md"))
    assert files, "docs/ subsystem missing"
    return files + [ROOT / "README.md"]


def test_docs_internal_links_resolve():
    broken = []
    for md in _doc_files():
        for m in _LINK.finditer(md.read_text()):
            target = m.group(1)
            if "://" in target or target.startswith("mailto:"):
                continue
            if not (md.parent / target).exists():
                broken.append(f"{md.relative_to(ROOT)} -> {target}")
    assert not broken, "broken internal doc links:\n" + "\n".join(broken)


def test_docs_required_pages_exist():
    for name in ("architecture.md", "paper_map.md", "adding_a_strategy.md",
                 "benchmarks.md"):
        assert (ROOT / "docs" / name).exists(), f"docs/{name} missing"


def test_every_registered_strategy_documented_in_paper_map():
    from repro.core.strategy import available_strategies

    text = (ROOT / "docs" / "paper_map.md").read_text()
    missing = [name for name in available_strategies()
               if f"`{name}`" not in text]
    assert not missing, (
        f"strategies missing from docs/paper_map.md: {missing} — every "
        "@register_strategy name must be documented there "
        "(docs/adding_a_strategy.md, step 2)")


def test_readme_links_docs():
    text = (ROOT / "README.md").read_text()
    for page in ("docs/architecture.md", "docs/paper_map.md",
                 "docs/adding_a_strategy.md"):
        assert page in text, f"README must link {page}"
