"""Unit tests for the shared device-plan library (``core/planops.py``) and
the legacy-checkpoint RNG migration shims.

The PlanOps ops are the building blocks every strategy's ``plan()`` now
composes on device; these tests pin their semantics against the host-numpy
logic they replaced (stable ranks, with-replacement draws, InfoBatch soft
pruning, threshold masks) and the checkpoint/migration contract.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import planops
from repro.core.strategy import rng_state


# --------------------------------------------------------------------------
# keys
# --------------------------------------------------------------------------


def test_strategy_key_convention():
    """One seed, decorrelated per-strategy streams — and deterministic."""
    k1 = planops.strategy_key(0, "baseline")
    k2 = planops.strategy_key(0, "baseline")
    np.testing.assert_array_equal(np.asarray(planops.key_data(k1)),
                                  np.asarray(planops.key_data(k2)))
    others = [planops.key_data(planops.strategy_key(0, n))
              for n in ("iswr", "sb", "kakurenbo")]
    for o in others:
        assert not np.array_equal(np.asarray(planops.key_data(k1)),
                                  np.asarray(o))
    assert not np.array_equal(
        np.asarray(planops.key_data(planops.strategy_key(1, "baseline"))),
        np.asarray(planops.key_data(k1)))


def test_key_data_roundtrip():
    key = planops.strategy_key(7, "x")
    restored = planops.load_key(np.asarray(planops.key_data(key)))
    np.testing.assert_array_equal(
        np.asarray(jax.random.permutation(key, 16)),
        np.asarray(jax.random.permutation(restored, 16)))


def test_migrate_legacy_rng_deterministic():
    """The same legacy numpy generator state always maps to the same key;
    unrecognisable payloads fall back to the seed convention."""
    st = rng_state(np.random.default_rng(42))
    k1, k2 = (planops.migrate_legacy_rng(st, 0, "baseline") for _ in range(2))
    np.testing.assert_array_equal(np.asarray(planops.key_data(k1)),
                                  np.asarray(planops.key_data(k2)))
    # survives the JSON round trip checkpoint metadata takes
    st_json = json.loads(json.dumps(st))
    k3 = planops.migrate_legacy_rng(st_json, 0, "baseline")
    np.testing.assert_array_equal(np.asarray(planops.key_data(k1)),
                                  np.asarray(planops.key_data(k3)))
    fallback = planops.migrate_legacy_rng({"bogus": 1}, 3, "name")
    np.testing.assert_array_equal(
        np.asarray(planops.key_data(fallback)),
        np.asarray(planops.key_data(planops.strategy_key(3, "name"))))


def test_restore_key_both_formats():
    key = planops.strategy_key(5, "s")
    new = planops.restore_key(
        {"arrays": {"rng_key": np.asarray(planops.key_data(key))},
         "host": {}}, 5, "s")
    np.testing.assert_array_equal(np.asarray(planops.key_data(new)),
                                  np.asarray(planops.key_data(key)))
    legacy = planops.restore_key(
        {"arrays": {}, "host": {"rng": rng_state(np.random.default_rng(1))}},
        5, "s")
    assert legacy is not None
    with pytest.raises(ValueError, match="cannot restore"):
        planops.restore_key({"arrays": {}, "host": {}}, 5, "s")


# --------------------------------------------------------------------------
# ordering
# --------------------------------------------------------------------------


def test_device_permutation_is_permutation():
    key = planops.strategy_key(0, "t")
    p = np.asarray(planops.device_permutation(key, 257))
    assert sorted(p.tolist()) == list(range(257))
    p2 = np.asarray(planops.device_permutation(key, 257))
    np.testing.assert_array_equal(p, p2)  # key-deterministic


def test_masked_order_kept_first():
    key = planops.strategy_key(1, "t")
    mask = np.zeros(100, bool)
    mask[::3] = True
    order, num_masked = planops.masked_order(key, jnp.asarray(mask))
    order, num_masked = np.asarray(order), int(num_masked)
    assert num_masked == int(mask.sum())
    assert sorted(order.tolist()) == list(range(100))
    assert not mask[order[: 100 - num_masked]].any()
    assert mask[order[100 - num_masked:]].all()


def test_stable_rank_order_matches_numpy_stable():
    r = np.random.default_rng(0)
    scores = r.integers(0, 5, 200).astype(np.float32)  # heavy ties
    rank = np.asarray(planops.stable_rank_order(jnp.asarray(scores)))
    order = np.argsort(scores, kind="stable")
    expect = np.zeros(200, np.int32)
    expect[order] = np.arange(200)
    np.testing.assert_array_equal(rank, expect)


def test_topk_hide_stable_ties():
    """FORGET's prune rule: k smallest, ties broken by lowest index — the
    two earliest zeros win over the third."""
    scores = jnp.asarray(np.array([1.0, 0.0, 0.0, 2.0, 0.0], np.float32))
    mask = np.asarray(planops.topk_hide(scores, jnp.int32(2)))
    np.testing.assert_array_equal(mask, [False, True, True, False, False])
    mask3 = np.asarray(planops.topk_hide(scores, jnp.int32(3)))
    np.testing.assert_array_equal(mask3, [False, True, True, False, True])


# --------------------------------------------------------------------------
# sampling
# --------------------------------------------------------------------------


def test_importance_probs_fill_and_normalise():
    loss = jnp.asarray([2.0, 4.0, 100.0], jnp.float32)
    valid = jnp.asarray([True, True, False])
    p = np.asarray(planops.importance_probs(loss, valid, 0.0))
    np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-6)
    # the unseen sample takes the mean seen loss (3.0), not its sentinel
    np.testing.assert_allclose(p, np.array([2, 4, 3]) / 9.0, rtol=1e-5)
    # nothing seen: uniform (fill 1.0 everywhere)
    p0 = np.asarray(planops.importance_probs(
        loss, jnp.zeros(3, bool), 0.0))
    np.testing.assert_allclose(p0, 1 / 3, rtol=1e-6)


def test_with_replacement_tracks_probabilities():
    n = 4000
    p = np.full(n, 0.5 / (n - 100))
    p[:100] = 0.5 / 100  # 100 hot samples carry half the mass
    key = planops.strategy_key(0, "draw")
    idx = np.asarray(planops.with_replacement(key, jnp.asarray(p, jnp.float32)))
    assert idx.shape == (n,) and idx.min() >= 0 and idx.max() < n
    hot = np.mean(idx < 100)
    assert 0.4 < hot < 0.6  # ~half the draws hit the hot set
    idx2 = np.asarray(planops.with_replacement(key, jnp.asarray(p, jnp.float32)))
    np.testing.assert_array_equal(idx, idx2)


def test_weighted_keep_infobatch_semantics():
    r = np.random.default_rng(0)
    loss = r.exponential(1.0, 512).astype(np.float32)
    valid = np.ones(512, bool)
    valid[::7] = False
    key = planops.strategy_key(0, "ib")
    prune, w = planops.weighted_keep(key, jnp.asarray(loss),
                                     jnp.asarray(valid), 0.5)
    prune, w = np.asarray(prune), np.asarray(w)
    mean = loss[valid].mean()
    below = valid & (loss < mean)
    assert prune[~below].sum() == 0          # only below-mean pruned
    assert 0 < prune.sum() < below.sum()     # soft, not total
    np.testing.assert_allclose(w[below & ~prune], 2.0, rtol=1e-6)
    np.testing.assert_allclose(w[~below], 1.0)
    # cold start: nothing valid -> no prune, uniform weights
    prune0, w0 = planops.weighted_keep(key, jnp.asarray(loss),
                                       jnp.zeros(512, bool), 0.5)
    assert int(np.asarray(prune0).sum()) == 0
    np.testing.assert_allclose(np.asarray(w0), 1.0)


# --------------------------------------------------------------------------
# threshold selection
# --------------------------------------------------------------------------


def test_threshold_mask_methods_agree_on_separated_losses():
    """Sort and histogram paths hide the same well-separated low-loss set;
    the Pallas-kernel histogram is bit-identical to the jnp histogram."""
    n = 1024
    r = np.random.default_rng(0)
    loss = np.concatenate([r.uniform(0, 0.1, 300),
                           r.uniform(10, 11, n - 300)]).astype(np.float32)
    perm = r.permutation(n)
    loss = loss[perm]
    valid = jnp.ones(n, bool)
    masks = {m: np.asarray(planops.threshold_mask(
        jnp.asarray(loss), valid, 300 / n, method=m))
        for m in ("sort", "histogram", "histogram_pallas")}
    np.testing.assert_array_equal(masks["histogram"],
                                  masks["histogram_pallas"])
    for m, mask in masks.items():
        assert mask.sum() == 300, m
        assert loss[mask].max() < loss[~mask].min(), m
