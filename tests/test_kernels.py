"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, assert_allclose."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import (flash_attention_ref, histogram_ref,
                               loss_confidence_ref, minmax_ref)
from repro.kernels.threshold_select import BIG, histogram_with_range
from repro.models.ssm import ssd_scan_ref


@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (2, 128, 4, 2, 16), (1, 256, 8, 8, 32), (2, 128, 6, 3, 64),
    (1, 512, 2, 1, 128),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, s, hq, hkv, d, causal, dtype, rng):
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, blk_q=64, blk_k=64)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,s,nh,p,n,chunk", [
    (2, 64, 3, 16, 8, 16), (1, 128, 2, 32, 16, 32), (2, 96, 1, 8, 4, 16),
])
def test_ssd_scan(b, s, nh, p, n, chunk, rng):
    x = jnp.asarray(rng.normal(size=(b, s, nh, p)), jnp.float32)
    dt = jnp.asarray(rng.normal(size=(b, s, nh)), jnp.float32)
    a_log = jnp.asarray(rng.uniform(0, 1, (nh,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    dsk = jnp.asarray(rng.normal(size=(nh,)), jnp.float32)
    y1, s1 = ssd_scan_ref(x, dt, a_log, bm, cm, dsk, chunk)
    y2, s2 = ops.ssd_scan(x, dt, a_log, bm, cm, dsk, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def test_ssd_scan_matches_sequential_recurrence(rng):
    """Chunked SSD == naive per-step recurrence (independent ground truth)."""
    b, s, nh, p, n, chunk = 1, 32, 2, 8, 4, 8
    x = rng.normal(size=(b, s, nh, p)).astype(np.float32)
    dtr = rng.normal(size=(b, s, nh)).astype(np.float32)
    a_log = rng.uniform(0, 1, (nh,)).astype(np.float32)
    bm = rng.normal(size=(b, s, n)).astype(np.float32)
    cm = rng.normal(size=(b, s, n)).astype(np.float32)
    dsk = np.zeros((nh,), np.float32)
    y_chunked, state_chunked = ssd_scan_ref(
        jnp.asarray(x), jnp.asarray(dtr), jnp.asarray(a_log), jnp.asarray(bm),
        jnp.asarray(cm), jnp.asarray(dsk), chunk)
    # naive recurrence
    a = -np.exp(a_log)
    dt = np.logaddexp(0, dtr)  # softplus
    h = np.zeros((b, nh, n, p), np.float32)
    ys = np.zeros_like(x)
    for t in range(s):
        decay = np.exp(dt[:, t] * a[None, :])                 # (b, nh)
        upd = np.einsum("bh,bn,bhp->bhnp", dt[:, t], bm[:, t], x[:, t])
        h = h * decay[:, :, None, None] + upd
        ys[:, t] = np.einsum("bn,bhnp->bhp", cm[:, t], h)
    np.testing.assert_allclose(np.asarray(y_chunked), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state_chunked), h, rtol=2e-4,
                               atol=2e-4)


@pytest.mark.parametrize("t,v", [(64, 512), (100, 1000), (256, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_loss_confidence(t, v, dtype, rng):
    lg = jnp.asarray(rng.normal(size=(t, v)) * 3, dtype)
    lab = jnp.asarray(rng.integers(0, v, (t,)), jnp.int32)
    ce1, c1, p1 = loss_confidence_ref(lg.astype(jnp.float32), lab)
    ce2, c2, p2 = ops.loss_confidence(lg, lab)
    np.testing.assert_allclose(np.asarray(ce1), np.asarray(ce2),
                               rtol=1e-3, atol=1e-3)
    assert bool((c1 == c2).all())
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n,bins", [(1000, 64), (4096, 512), (3000, 128)])
def test_histogram(n, bins, rng):
    loss = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    valid = jnp.asarray(rng.random(n) < 0.8)
    lo, hi = jnp.float32(-3), jnp.float32(3)
    h1 = histogram_ref(loss, valid, lo, hi, bins)
    h2 = ops.loss_histogram(loss, valid, lo, hi, bins)
    assert bool((h1 == h2).all())
    assert int(h2.sum()) == int(valid.sum())


@pytest.mark.parametrize("n", [1000, 2048, 3000])
def test_minmax(n, rng):
    """The range pass matches the masked-reduction oracle exactly."""
    loss = jnp.asarray(rng.normal(size=(n,)) * 5, jnp.float32)
    valid = jnp.asarray(rng.random(n) < 0.8)
    lo_ref, hi_ref = minmax_ref(loss, valid)
    lo, hi = ops.loss_minmax(loss, valid)
    assert float(lo) == float(lo_ref)
    assert float(hi) == float(hi_ref)


def test_minmax_all_invalid(rng):
    """No valid samples -> the raw [BIG, -BIG] sentinels (callers fold)."""
    loss = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    lo, hi = ops.loss_minmax(loss, jnp.zeros(256, bool))
    assert float(lo) == pytest.approx(BIG, rel=1e-6)
    assert float(hi) == pytest.approx(-BIG, rel=1e-6)


def test_histogram_with_range_fused(rng):
    """Range pass + histogram pass chained on device == two-step oracle."""
    n, bins = 4096, 512
    loss = jnp.asarray(rng.exponential(1.0, n), jnp.float32)
    valid = jnp.asarray(rng.random(n) < 0.7)
    hist, lo_raw, hi_raw = histogram_with_range(loss, valid, bins=bins)
    lo_ref, hi_ref = minmax_ref(loss, valid)
    assert float(lo_raw) == float(lo_ref)
    assert float(hi_raw) == float(hi_ref)
    h_ref = histogram_ref(loss, valid, jnp.minimum(lo_raw, hi_raw), hi_raw,
                          bins)
    assert bool((hist == h_ref).all())
    assert int(hist.sum()) == int(valid.sum())


def test_model_metrics_match_kernel(rng):
    """transformer.token_metrics (used in training) == fused kernel output."""
    from repro.models.transformer import token_metrics
    t, v = 32, 257
    lg = jnp.asarray(rng.normal(size=(t, v)) * 2, jnp.float32)
    lab = jnp.asarray(rng.integers(0, v, (t,)), jnp.int32)
    ce_m, cor_m, p_m = token_metrics(lg, lab)
    ce_k, cor_k, p_k = ops.loss_confidence(lg, lab)
    np.testing.assert_allclose(np.asarray(ce_m), np.asarray(ce_k), rtol=1e-4,
                               atol=1e-4)
    assert bool((cor_m == cor_k).all())
    np.testing.assert_allclose(np.asarray(p_m), np.asarray(p_k), rtol=1e-4,
                               atol=1e-4)
