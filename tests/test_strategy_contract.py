"""Registry-driven contract test: every registered SampleStrategy must
satisfy the protocol — plan an epoch, observe a batch, produce sane batch
weights, survive a bit-exact state_dict round-trip, and report work
accounting from on_epoch_end."""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EpochPlan, STRATEGIES, available_strategies, make_strategy
from repro.core.strategy import SampleStrategy

N = 64
BATCH = 16
EXPECTED = {"baseline", "kakurenbo", "random", "iswr", "forget", "sb",
            "gradmatch", "infobatch"}


def _make(name, seed=0):
    return make_strategy(name, N, cfg=None, seed=seed, num_classes=4,
                         total_epochs=4)


def _observe_epoch(s, rng, epoch):
    """Drive one epoch the way the trainer does; returns the plan."""
    plan = s.plan(epoch)
    for start in range(0, len(plan.visible_indices) - BATCH + 1, BATCH):
        idx = np.asarray(plan.visible_indices[start : start + BATCH])
        loss = jnp.asarray(rng.exponential(1.0, BATCH), jnp.float32)
        pa = jnp.asarray(rng.random(BATCH) < 0.7)
        pc = jnp.asarray(rng.random(BATCH), jnp.float32)
        if s.needs_batch_loss:
            w = s.select_batch(idx, np.asarray(loss))
            assert w is not None and len(w) == len(idx)
            assert np.all(np.asarray(w) >= 0)
        else:
            w = s.batch_weights(idx)
            assert w is None or len(w) == len(idx)
        s.observe(idx, loss, pa, pc, epoch)
    if plan.needs_refresh:
        def eval_forward(idx):
            b = len(idx)
            return (jnp.ones((b,), jnp.float32), jnp.ones((b,), bool),
                    jnp.ones((b,), jnp.float32))
        n_ref = s.on_epoch_end(plan, eval_forward, BATCH)
        assert isinstance(n_ref, int) and n_ref == len(plan.hidden_indices)
    return plan


def test_registry_is_complete():
    assert EXPECTED <= set(available_strategies())


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_strategy_contract(name):
    s = _make(name)
    assert isinstance(s, SampleStrategy)
    assert s.name == name
    rng = np.random.default_rng(0)

    plan = _observe_epoch(s, rng, 0)
    assert isinstance(plan, EpochPlan)
    assert plan.epoch == 0
    assert len(plan.visible_indices) > 0
    assert 0.0 <= plan.hidden_fraction <= 1.0
    assert plan.lr_scale > 0.0
    # visible/hidden never overlap
    assert not set(np.asarray(plan.visible_indices).tolist()) & set(
        np.asarray(plan.hidden_indices).tolist())


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_strategy_state_roundtrip_bit_exact(name):
    s = _make(name)
    rng = np.random.default_rng(1)
    _observe_epoch(s, rng, 0)
    _observe_epoch(s, rng, 1)

    sd = s.state_dict()
    # host part must survive the checkpoint metadata path (JSON)
    host = json.loads(json.dumps(sd["host"]))

    s2 = _make(name, seed=123)  # different seed: load must overwrite it
    s2.load_state_dict({"arrays": sd["arrays"], "host": host})

    sd2 = s2.state_dict()
    la, lb = jax.tree.leaves(sd["arrays"]), jax.tree.leaves(sd2["arrays"])
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert json.loads(json.dumps(sd2["host"])) == host

    # ...and the restored strategy continues the exact trajectory: the next
    # plan draws only from strategy-internal RNG + restored state, so it
    # must be identical index-for-index.
    p_ref = s.plan(2)
    p_clone = s2.plan(2)
    np.testing.assert_array_equal(np.asarray(p_ref.visible_indices),
                                  np.asarray(p_clone.visible_indices))
    np.testing.assert_array_equal(np.asarray(p_ref.hidden_indices),
                                  np.asarray(p_clone.hidden_indices))
    assert p_ref.lr_scale == p_clone.lr_scale
