"""Registry-driven contract test: every registered SampleStrategy must
satisfy the protocol — plan an epoch, observe a batch, produce sane batch
weights, survive a bit-exact state_dict round-trip, and report work
accounting from on_epoch_end."""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EpochPlan, STRATEGIES, available_strategies, make_strategy
from repro.core.strategy import SampleStrategy

N = 64
BATCH = 16
EXPECTED = {"baseline", "kakurenbo", "random", "iswr", "forget", "sb",
            "gradmatch", "infobatch"}


def _make(name, seed=0):
    return make_strategy(name, N, cfg=None, seed=seed, num_classes=4,
                         total_epochs=4)


def _observe_epoch(s, rng, epoch):
    """Drive one epoch the way the trainer does; returns the plan."""
    plan = s.plan(epoch)
    for start in range(0, len(plan.visible_indices) - BATCH + 1, BATCH):
        idx = np.asarray(plan.visible_indices[start : start + BATCH])
        loss = jnp.asarray(rng.exponential(1.0, BATCH), jnp.float32)
        pa = jnp.asarray(rng.random(BATCH) < 0.7)
        pc = jnp.asarray(rng.random(BATCH), jnp.float32)
        w = s.batch_weights(idx)
        assert w is None or len(w) == len(idx)
        if s.fused_select is not None:
            # In-step selection: weights are non-negative, survivors keep
            # the batch-mean loss unbiased, and the device state advances.
            state = s.get_device_state()
            w_sel, state = s.fused_select(state, loss)
            assert len(w_sel) == len(idx)
            assert np.all(np.asarray(w_sel) >= 0)
            s.set_device_state(state)
        s.observe(idx, loss, pa, pc, epoch)
    if plan.needs_refresh:
        def eval_forward(idx):
            b = len(idx)
            return (jnp.ones((b,), jnp.float32), jnp.ones((b,), bool),
                    jnp.ones((b,), jnp.float32))
        n_ref = s.on_epoch_end(plan, eval_forward, BATCH)
        assert isinstance(n_ref, int) and n_ref == len(plan.hidden_indices)
    return plan


def test_registry_is_complete():
    assert EXPECTED <= set(available_strategies())


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_strategy_contract(name):
    s = _make(name)
    assert isinstance(s, SampleStrategy)
    assert s.name == name
    rng = np.random.default_rng(0)

    plan = _observe_epoch(s, rng, 0)
    assert isinstance(plan, EpochPlan)
    assert plan.epoch == 0
    assert len(plan.visible_indices) > 0
    assert 0.0 <= plan.hidden_fraction <= 1.0
    assert plan.lr_scale > 0.0
    # visible/hidden never overlap
    assert not set(np.asarray(plan.visible_indices).tolist()) & set(
        np.asarray(plan.hidden_indices).tolist())


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_strategy_state_roundtrip_bit_exact(name):
    s = _make(name)
    rng = np.random.default_rng(1)
    _observe_epoch(s, rng, 0)
    _observe_epoch(s, rng, 1)

    sd = s.state_dict()
    # host part must survive the checkpoint metadata path (JSON)
    host = json.loads(json.dumps(sd["host"]))

    s2 = _make(name, seed=123)  # different seed: load must overwrite it
    s2.load_state_dict({"arrays": sd["arrays"], "host": host})

    sd2 = s2.state_dict()
    la, lb = jax.tree.leaves(sd["arrays"]), jax.tree.leaves(sd2["arrays"])
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert json.loads(json.dumps(sd2["host"])) == host

    # ...and the restored strategy continues the exact trajectory: the next
    # plan draws only from strategy-internal RNG + restored state, so it
    # must be identical index-for-index.
    p_ref = s.plan(2)
    p_clone = s2.plan(2)
    np.testing.assert_array_equal(np.asarray(p_ref.visible_indices),
                                  np.asarray(p_clone.visible_indices))
    np.testing.assert_array_equal(np.asarray(p_ref.hidden_indices),
                                  np.asarray(p_clone.hidden_indices))
    assert p_ref.lr_scale == p_clone.lr_scale


def test_all_strategies_support_scan():
    """The PlanOps bar: every registered strategy plans on device and can
    run its epochs under the scanned engine."""
    for name in sorted(EXPECTED):
        assert _make(name).supports_scan, name


# --------------------------------------------------------------------------
# legacy (pre-PlanOps) checkpoint migration
# --------------------------------------------------------------------------

def _legacy_state_dict(name, s):
    """The state_dict shape the pre-PlanOps strategies checkpointed: host
    numpy Generator states instead of device rng_key leaves."""
    from repro.core.strategy import rng_state
    rng = rng_state(np.random.default_rng(7))
    arrays = {}
    host = {"rng": rng}
    if name in ("iswr", "infobatch"):
        arrays["state"] = s._inner.state
    elif name == "forget":
        arrays["state"] = s._inner.state
        arrays["pruned"] = np.zeros(N, bool)
        host["restarted"] = False
    elif name == "gradmatch":
        arrays["subset"] = np.arange(N)
        arrays["weights"] = np.ones(N, np.float32)
    elif name == "sb":
        arrays["hist"] = np.linspace(0.1, 1.0, 50).astype(np.float32)
        host["inner_rng"] = rng_state(np.random.default_rng(8))
    elif name == "random":
        arrays["state"] = s._inner.state
        arrays["inner_key"] = np.asarray(s._inner.key_data())
    return {"arrays": arrays, "host": json.loads(json.dumps(host))}


@pytest.mark.parametrize(
    "name", sorted(EXPECTED - {"kakurenbo"}))  # kakurenbo was always keyed
def test_legacy_state_dict_still_restores(name):
    """Pre-PlanOps checkpoints (host numpy RNG states) still restore: the
    migration shim derives the device key deterministically, so two restores
    of the same legacy payload continue on identical plans."""
    clones = []
    for seed in (11, 22):  # construction seed must not leak through
        s = _make(name, seed=seed)
        s.load_state_dict(_legacy_state_dict(name, _make(name)))
        clones.append(s)
    p1, p2 = (c.plan(0) for c in clones)
    np.testing.assert_array_equal(np.asarray(p1.visible_indices),
                                  np.asarray(p2.visible_indices))
