"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
one train step on CPU, asserting output shapes and no NaNs (the full configs
are exercised abstractly by the dry-run only)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.models import build_model
from repro.optim import make_optimizer

B, S = 2, 32


def _batch(cfg, rng):
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks,
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "mask": jnp.ones((B, S), bool)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.encoder_input_dim)), jnp.float32)
        batch["tokens"] = batch["tokens"][:, : S // 4]
        batch["labels"] = batch["labels"][:, : S // 4]
        batch["mask"] = batch["mask"][:, : S // 4]
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patch_tokens, 1024)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_train_step(arch, rng):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, rng)
    opt = make_optimizer("sgd", momentum=0.9)
    opt_state = opt.init(params)

    def loss(p):
        return model.loss_and_metrics(p, batch)

    (scalar, (lv, pa, pc)), grads = jax.value_and_grad(
        loss, has_aux=True)(params)
    params2, _ = opt.update(grads, opt_state, params, jnp.float32(0.1))

    nb = batch["tokens"].shape[0]
    assert lv.shape == (nb,) and pa.shape == (nb,) and pc.shape == (nb,)
    assert np.isfinite(float(scalar)), arch
    assert bool(jnp.all(jnp.isfinite(lv)))
    assert bool(jnp.all((pc >= 0) & (pc <= 1.0 + 1e-5)))
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, leaf: a + float(jnp.sum(jnp.abs(leaf))),
        jax.tree.map(lambda a, b: a - b, params, params2), 0.0)
    assert moved > 0.0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_decode_step(arch, rng):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(B, 16, dtype=jnp.float32)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache = model.decode_step(params, tok, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["len"]) == 1
    logits2, cache = model.decode_step(params, tok, cache)
    assert int(cache["len"]) == 2


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-130m", "hymba-1.5b",
                                  "phi3.5-moe-42b-a6.6b"])
def test_reduced_prefill_matches_forward(arch, rng):
    import dataclasses
    cfg = ARCHS[arch].reduced()
    if cfg.moe is not None:
        # Capacity-based routing drops tokens as a function of the *whole*
        # batch (T=66 in the reference forward vs T=2 in decode), so
        # prefill/decode path equivalence is only well-defined when no
        # expert overflows; give the smoke config headroom so none do.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)
    from repro.models import transformer
    logits_full, _, _ = transformer.forward(cfg, model.ctx, params,
                                            {"tokens": toks})
    lg, cache = model.prefill(params, {"tokens": toks[:, :S]}, max_len=S + 1)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(logits_full[:, S - 1]),
                               rtol=2e-4, atol=2e-4)
    lg2, _ = model.decode_step(params, toks[:, S: S + 1], cache)
    np.testing.assert_allclose(np.asarray(lg2[:, 0]),
                               np.asarray(logits_full[:, -1]),
                               rtol=3e-3, atol=3e-3)
