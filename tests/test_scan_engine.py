"""Scanned epoch engine: bit-identity with the host loop (the tentpole bar).

The scanned engine (``train/engines.py::ScanEpochEngine``) changes *how* an
epoch is dispatched — device-resident data, gather-based batch assembly,
``scan_steps`` train steps per ``lax.scan`` dispatch, one loss fetch per
epoch — but must not change a single bit of *what* is computed: per-epoch
losses, parameter trajectories, the strategy's ``SampleState``, hidden and
move-back sets, and checkpoint/restart behaviour are all required to be
identical to the host-loop engine, for every strategy that opts in
(``SampleStrategy.supports_scan``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ForgetConfig, KakurenboConfig, LRSchedule, available_strategies,
)
from repro.data import SyntheticClassification
from repro.data.pipeline import Pipeline, epoch_index_plan
from repro.models import cnn
from repro.train import Trainer, TrainConfig
from repro.train.engines import HostLoopEngine, ScanEpochEngine

CFG_MODEL = cnn.CNNConfig(image_size=8, widths=(8,), hidden=16)

#: The whole registry must run scanned — the PlanOps acceptance bar.
ALL_STRATEGIES = ("baseline", "forget", "gradmatch", "infobatch", "iswr",
                  "kakurenbo", "random", "sb")


def _fns():
    def init_params(rng):
        return cnn.init(rng, CFG_MODEL)

    def loss_fn(params, batch):
        logits = cnn.forward(params, CFG_MODEL, batch["images"])
        loss, pa, pc = cnn.per_sample_metrics(logits, batch["labels"])
        w = batch.get("weight")
        scalar = jnp.mean(loss * w) if w is not None else jnp.mean(loss)
        return scalar, (loss, pa, pc)

    return init_params, loss_fn


def _mk(engine, strategy="kakurenbo", epochs=3, num_samples=256, seed=0,
        checkpoint_dir=None, **tc_kw):
    ds = SyntheticClassification(num_samples=num_samples, image_size=8,
                                 seed=0)
    init_params, loss_fn = _fns()
    tc = TrainConfig(
        epochs=epochs, batch_size=64, strategy=strategy, engine=engine,
        lr=LRSchedule(0.05, "cosine", epochs, 1),
        kakurenbo=KakurenboConfig(max_fraction=0.3,
                                  fraction_milestones=(0, 1, 2, 3)),
        # warmup inside the run so FORGET's prune+restart is exercised
        forget=ForgetConfig(fraction=0.3, warmup_epochs=2),
        seed=seed, checkpoint_dir=checkpoint_dir,
        checkpoint_every=1 if checkpoint_dir else 0, **tc_kw)
    return Trainer(tc, init_params, loss_fn, ds, None)


def _run_capturing_plans(tr):
    plans = []
    orig = tr.strategy.plan
    tr.strategy.plan = lambda e: (plans.append(orig(e)) or plans[-1])
    hist = tr.run()
    return hist, plans


def _assert_same_trajectory(tr_a, tr_b, hist_a, hist_b, plans_a, plans_b,
                            tag):
    assert [h.train_loss for h in hist_a] == [h.train_loss for h in hist_b], tag
    assert ([(h.fwd_samples, h.bwd_samples) for h in hist_a]
            == [(h.fwd_samples, h.bwd_samples) for h in hist_b]), tag
    for a, b in zip(jax.tree.leaves(tr_a.params), jax.tree.leaves(tr_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=tag)
    for pa, pb in zip(plans_a, plans_b):
        np.testing.assert_array_equal(pa.visible_indices, pb.visible_indices,
                                      err_msg=tag)
        np.testing.assert_array_equal(np.sort(pa.hidden_indices),
                                      np.sort(pb.hidden_indices), err_msg=tag)
        np.testing.assert_array_equal(pa.moveback_indices,
                                      pb.moveback_indices, err_msg=tag)
    state_a = tr_a.strategy.get_device_state()
    state_b = tr_b.strategy.get_device_state()
    if state_a is not None:
        for a, b in zip(jax.tree.leaves(state_a), jax.tree.leaves(state_b)):
            if jnp.issubdtype(a.dtype, jax.dtypes.prng_key):
                a, b = jax.random.key_data(a), jax.random.key_data(b)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=tag)


# --------------------------------------------------------------------------
# epoch plan layout
# --------------------------------------------------------------------------


def test_epoch_index_plan_matches_pipeline_batches(rng):
    """The (num_steps, B) plan rows are exactly what Pipeline.batches
    yields, including the cycled-from-front padded final batch."""
    for n, bs in [(256, 64), (300, 64), (63, 64), (64, 64), (130, 64)]:
        idx = rng.permutation(n)
        plan = epoch_index_plan(idx, bs)
        rows = [i for i, _ in Pipeline(lambda x: {"x": x}, bs).batches(idx)]
        assert plan.shape == (len(rows), bs)
        for r, row in enumerate(rows):
            np.testing.assert_array_equal(plan[r], row)


def test_epoch_index_plan_short_epoch_is_empty():
    assert epoch_index_plan(np.arange(10), 64).shape == (0, 64)


# --------------------------------------------------------------------------
# engine parity
# --------------------------------------------------------------------------


def test_registry_is_fully_scan_capable():
    """The PlanOps acceptance bar: every registered strategy reports
    supports_scan and the parity suite below covers the whole registry."""
    assert tuple(available_strategies()) == ALL_STRATEGIES
    from repro.core import make_strategy
    for name in ALL_STRATEGIES:
        s = make_strategy(name, 64, seed=0, num_classes=4, total_epochs=4)
        assert s.supports_scan, name


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_scan_engine_bit_identical_to_host_loop(strategy):
    """Same losses, params, SampleState, hidden/move-back sets and work
    accounting from both engines, for the FULL strategy registry — and O(1)
    host syncs from the scanned epoch (the plan materialisation only)."""
    tr_s = _mk("scan", strategy)
    tr_h = _mk("host", strategy)
    assert isinstance(tr_s.engine, ScanEpochEngine)
    assert isinstance(tr_h.engine, HostLoopEngine)
    hist_s, plans_s = _run_capturing_plans(tr_s)
    hist_h, plans_h = _run_capturing_plans(tr_h)
    _assert_same_trajectory(tr_s, tr_h, hist_s, hist_h, plans_s, plans_h,
                            strategy)
    assert all(h.engine == "scan" for h in hist_s)
    # device-planned scanned epochs: host_syncs == the per-epoch plan cost,
    # never O(batches)
    assert all(h.host_syncs <= 1 for h in hist_s)


@pytest.mark.parametrize("scan_steps", [1, 3, 64])
def test_scan_block_size_invariance(scan_steps):
    """K=1 (per-step scan blocks), K=3 (remainder blocks every epoch) and
    K=64 (the whole epoch in one dispatch) are all bit-identical."""
    ref = _mk("scan", scan_steps=8)
    hist_ref = ref.run()
    tr = _mk("scan", scan_steps=scan_steps)
    hist = tr.run()
    assert [h.train_loss for h in hist] == [h.train_loss for h in hist_ref]
    for a, b in zip(jax.tree.leaves(tr.params), jax.tree.leaves(ref.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_legacy_fused_off_still_forces_host_loop():
    """fused_observe=False is the differential-parity path: it must run the
    host loop (per-batch observe) even under engine='auto', and still match
    the scanned default bit for bit."""
    tr_legacy = _mk("auto", fused_observe=False)
    assert isinstance(tr_legacy.engine, HostLoopEngine)
    tr_scan = _mk("auto")
    assert isinstance(tr_scan.engine, ScanEpochEngine)
    hist_l = tr_legacy.run()
    hist_s = tr_scan.run()
    assert [h.train_loss for h in hist_s] == [h.train_loss for h in hist_l]
    for a, b in zip(jax.tree.leaves(tr_scan.params),
                    jax.tree.leaves(tr_legacy.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sb_scans_with_fused_select():
    """Selective-Backprop's forward-then-mask flow is the in-step
    fused_select hook: auto picks the scanned engine, the backward count
    reflects the surviving subset, and the select state advances."""
    tr = _mk("auto", "sb", epochs=2)
    assert isinstance(tr.engine, ScanEpochEngine)
    hist = tr.run()
    # after the bootstrap window the Bernoulli mask drops samples, so the
    # backward count falls below the forward count
    assert hist[-1].bwd_samples < hist[-1].fwd_samples
    assert hist[-1].bwd_samples > 0
    assert int(tr.strategy.get_device_state()["count"]) > 0


def test_host_observing_strategy_keeps_host_loop():
    """Engine selection stays capability-driven: an external strategy with a
    host-side observe() and no fused_observe cannot scan — auto picks the
    host loop and forcing engine='scan' is a config error."""
    from repro.core.strategy import EpochPlan, SampleStrategy

    class HostObserver(SampleStrategy):
        def plan(self, epoch):
            return EpochPlan(epoch=epoch,
                             visible_indices=np.arange(self.num_samples))

        def observe(self, indices, loss, pa, pc, epoch):
            self.seen = np.asarray(indices)

    ds = SyntheticClassification(num_samples=128, image_size=8, seed=0)
    init_params, loss_fn = _fns()
    tc = TrainConfig(epochs=1, batch_size=64, engine="auto",
                     lr=LRSchedule(0.05, "cosine", 1, 1), seed=0)
    tr = Trainer(tc, init_params, loss_fn, ds, None,
                 strategy=HostObserver(ds.num_samples))
    assert isinstance(tr.engine, HostLoopEngine)
    tr.run()
    with pytest.raises(ValueError, match="scan"):
        Trainer(dataclasses.replace(tc, engine="scan"), init_params, loss_fn,
                ds, None, strategy=HostObserver(ds.num_samples))


def test_engine_config_validation():
    """Contradictory or unknown engine configs fail fast; device_data=False
    disables auto-scan (and never materialises the dataset)."""
    with pytest.raises(ValueError, match="device_data"):
        _mk("scan", device_data=False)
    with pytest.raises(ValueError, match="engine"):
        _mk("scanned")
    tr = _mk("auto", device_data=False)
    assert isinstance(tr.engine, HostLoopEngine)
    assert tr._device_data is None
    # lazy placement: building a scan trainer doesn't materialise either
    assert _mk("scan")._device_data is None


def test_warmup_compiles_all_block_shapes_without_training():
    """warmup() runs dummy blocks on a cloned carry: every dispatchable
    block shape ({K} + power-of-2 remainders) compiles, the real train
    state is untouched, and the subsequent run is still bit-identical."""
    tr = _mk("scan", scan_steps=8)
    before = [np.asarray(x).copy() for x in jax.tree.leaves(tr.params)]
    assert tr.engine.warmup() == 4  # 8, then 1/2/4 remainder lengths
    for a, b in zip(jax.tree.leaves(tr.params), before):
        np.testing.assert_array_equal(np.asarray(a), b)
    hist = tr.run()
    ref = _mk("host").run()
    assert [h.train_loss for h in hist] == [h.train_loss for h in ref]


def test_scan_engine_with_grad_compression():
    """The EF residual rides the scan carry: compressed-gradient training is
    engine-independent too."""
    tr_s = _mk("scan", "baseline", grad_compression=True)
    tr_h = _mk("host", "baseline", grad_compression=True)
    hist_s = tr_s.run()
    hist_h = tr_h.run()
    assert [h.train_loss for h in hist_s] == [h.train_loss for h in hist_h]
    for a, b in zip(jax.tree.leaves(tr_s.ef_state),
                    jax.tree.leaves(tr_h.ef_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# restart
# --------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_scan_mid_epoch_crash_checkpoint_restart(strategy, tmp_path):
    """A crash *between scan blocks* mid-epoch leaves live (non-donated)
    buffers — state_dict works for checkpoint-on-fault — and restarting from
    the last epoch-boundary checkpoint replays the exact trajectory, for
    every (newly) device-planned strategy in the registry."""
    ref = _mk("scan", strategy, epochs=4, scan_steps=1)
    hist_ref = ref.run()

    tr = _mk("scan", strategy, epochs=4, scan_steps=1,
             checkpoint_dir=str(tmp_path / "ckpt"))
    tr.run(2)  # checkpoints after every epoch
    # crash inside epoch 2 after the first scan block
    orig_block = tr.engine._block
    calls = {"n": 0}

    def bomb(carry, xs, epoch, lr):
        if calls["n"] >= 1:
            raise RuntimeError("injected mid-epoch failure")
        calls["n"] += 1
        return orig_block(carry, xs, epoch, lr)

    tr.engine._block = bomb
    with pytest.raises(RuntimeError, match="mid-epoch"):
        tr.run_epoch(2)
    assert calls["n"] == 1  # at least one block trained before the crash
    # checkpoint-on-fault contract: the handed-back carry is fully live
    sd = tr.strategy.state_dict()
    jax.block_until_ready(jax.tree.leaves(sd["arrays"]))

    tr2 = _mk("scan", strategy, epochs=4, scan_steps=1,
              checkpoint_dir=str(tmp_path / "ckpt"), seed=99)
    assert tr2.restore_latest()
    assert tr2.epoch == 2
    hist2 = tr2.run()
    assert hist2[-1].train_loss == hist_ref[-1].train_loss
    for a, b in zip(jax.tree.leaves(tr2.params), jax.tree.leaves(ref.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scan_checkpoint_restart_bit_exact(tmp_path):
    """Epoch-boundary crash/restart under the scanned engine (the
    test_train_fault contract, re-run through scan dispatch)."""
    ref = _mk("scan", epochs=4)
    ref.run()
    tr = _mk("scan", epochs=4, checkpoint_dir=str(tmp_path / "ckpt"))
    with pytest.raises(RuntimeError):
        tr.run(4, fail_at_epoch=2)
    tr2 = _mk("scan", epochs=4, checkpoint_dir=str(tmp_path / "ckpt"))
    assert tr2.restore_latest()
    tr2.run()
    for a, b in zip(jax.tree.leaves(tr2.params), jax.tree.leaves(ref.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(tr2.sampler.state.loss), np.asarray(ref.sampler.state.loss))
