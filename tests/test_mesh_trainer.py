"""Mesh-size invariance of the sharded trainer (the tentpole bar).

The mesh-sharded trainer promises that training is a pure function of the
config — not of the mesh: hidden masks, move-back sets, the epoch batch
order, per-epoch losses and the final parameters must be *bit-identical*
between a ``(1,)`` and an ``(8,)`` mesh (host-simulated via
``--xla_force_host_platform_device_count=8``).  Two mechanisms make that
hold, both exercised here:

- the cross-shard plan step (``core/kakurenbo.py::_plan_step``): psum'd
  histograms + replicated shuffle key give every shard the same global
  decisions;
- the chunk-major deterministic gradient fold
  (``train/trainer.py::_jit_steps_mesh``): the reduction tree depends only
  on ``grad_chunks``, never on the mesh size.

Runs in subprocesses because the device count must be forced before jax
initialises its backends.
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

_PRELUDE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp

from repro.core import ForgetConfig, KakurenboConfig, LRSchedule
from repro.data import SyntheticClassification
from repro.models import cnn
from repro.train import Trainer, TrainConfig

MODEL = cnn.CNNConfig(image_size=8, widths=(8,), hidden=16)

def logits_fn(params, batch):
    return cnn.forward(params, MODEL, batch["images"])

def loss_fn(params, batch):
    logits = logits_fn(params, batch)
    loss, pa, pc = cnn.per_sample_metrics(logits, batch["labels"])
    w = batch.get("weight")
    scalar = jnp.mean(loss * w) if w is not None else jnp.mean(loss)
    return scalar, (loss, pa, pc)

def make_trainer(mesh_shape, epochs=3, selection="histogram",
                 compression=False, strategy="kakurenbo", fused=True,
                 checkpoint_dir=None, **tc_kw):
    ds = SyntheticClassification(num_samples=512, image_size=8, seed=0)
    kc = KakurenboConfig(selection=selection, max_fraction=0.3,
                         fraction_milestones=(0, 1, 2, 3))
    tc = TrainConfig(epochs=epochs, batch_size=64, strategy=strategy,
                     kakurenbo=kc, lr=LRSchedule(0.05, "cosine", epochs, 1),
                     forget=ForgetConfig(fraction=0.3, warmup_epochs=2),
                     mesh_shape=mesh_shape, grad_chunks=8,
                     grad_compression=compression, fused_observe=fused,
                     seed=0, checkpoint_dir=checkpoint_dir,
                     checkpoint_every=1 if checkpoint_dir else 0, **tc_kw)
    return Trainer(tc, lambda r: cnn.init(r, MODEL), loss_fn, ds, None,
                   logits_fn=logits_fn)

def run(mesh_shape, **kw):
    tr = make_trainer(mesh_shape, **kw)
    plans = []
    orig = tr.strategy.plan
    tr.strategy.plan = lambda e: (plans.append(orig(e)) or plans[-1])
    hist = tr.run()
    recs = []
    for p, h in zip(plans, hist):
        recs.append({
            "hidden": np.sort(p.hidden_indices),
            "moveback": np.asarray(p.moveback_indices),
            "order": p.visible_indices.copy(),
            "loss": h.train_loss,
            "host_syncs": h.host_syncs,
        })
    return recs, jax.tree.leaves(tr.params)

def assert_bit_identical(a, b, tag):
    (ra, pa), (rb, pb) = a, b
    assert len(ra) == len(rb)
    for e, (x, y) in enumerate(zip(ra, rb)):
        assert np.array_equal(x["hidden"], y["hidden"]), (tag, e, "hidden")
        assert np.array_equal(x["moveback"], y["moveback"]), (tag, e, "mb")
        assert np.array_equal(x["order"], y["order"]), (tag, e, "order")
        # exact float equality — the loss curves must be bit-identical
        assert x["loss"] == y["loss"], (tag, e, x["loss"], y["loss"])
    for l1, l2 in zip(pa, pb):
        assert np.array_equal(np.asarray(l1), np.asarray(l2)), (tag, "params")
"""


def _run(script: str, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", _PRELUDE + script],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=timeout)
    assert "MESH_OK" in res.stdout, res.stdout + res.stderr
    return res.stdout


@pytest.mark.parametrize("selection", ["sort", "histogram", "histogram_pallas"])
def test_mesh_size_invariance_bit_identical(selection):
    """(1,) vs (8,) meshes: masks, move-back sets, batch order, per-epoch
    losses and final params all bit-identical, for every selection method
    (histogram* through the shard_map psum plan, sort through the global
    GSPMD argsort)."""
    _run(f"""
a = run((1,), selection={selection!r})
b = run((8,), selection={selection!r})
assert_bit_identical(a, b, {selection!r})
# the plan is still one host sync per epoch under the mesh
assert all(r["host_syncs"] == 1 for r in a[0]), a[0]
assert all(r["host_syncs"] == 1 for r in b[0]), b[0]
# selection actually hides something by the last epoch (non-vacuous test)
assert len(a[0][-1]["hidden"]) > 0
print("MESH_OK")
""")


def test_mesh_matches_legacy_observe_path():
    """fused_observe=False (per-batch host scatters) is bit-identical to the
    fused path under the mesh, like it is on a single device."""
    _run("""
a = run((8,), fused=True)
b = run((8,), fused=False)
assert_bit_identical(a, b, "fused-vs-legacy")
print("MESH_OK")
""")


def test_mesh_compression_convergence_smoke():
    """Error-feedback gradient compression inside the sharded step: still
    converges, stays close to the uncompressed run, and is itself
    mesh-size-invariant (quantization happens on the folded replicated
    grads)."""
    _run("""
on1 = run((1,), compression=True)
on8 = run((8,), compression=True)
assert_bit_identical(on1, on8, "compression")
off8 = run((8,), compression=False)
lon = [r["loss"] for r in on8[0]]
loff = [r["loss"] for r in off8[0]]
assert lon[-1] < lon[0], lon                      # converges
assert np.allclose(lon, loff, rtol=0.1), (lon, loff)  # tracks uncompressed
print("MESH_OK")
""")


def test_mesh_checkpoint_restart_bit_exact(tmp_path):
    """Crash + restore under the (8,) mesh resumes the exact trajectory —
    with compression on, so the sharded SampleState, the replicated RNG key
    AND the error-feedback residual all round-trip through the
    checkpoint."""
    _run(f"""
import shutil
ckpt = {str(tmp_path / "ckpt")!r}
ref = run((8,), epochs=4, compression=True)
tr = make_trainer((8,), epochs=4, compression=True, checkpoint_dir=ckpt)
try:
    tr.run(fail_at_epoch=2)
except RuntimeError:
    pass
tr2 = make_trainer((8,), epochs=4, compression=True, checkpoint_dir=ckpt)
assert tr2.restore_latest()
hist = tr2.run()
assert hist[-1].train_loss == ref[0][-1]["loss"], (hist[-1].train_loss, ref[0][-1]["loss"])
for l1, l2 in zip(jax.tree.leaves(tr2.params), ref[1]):
    assert np.array_equal(np.asarray(l1), np.asarray(l2))
shutil.rmtree(ckpt, ignore_errors=True)
print("MESH_OK")
""")


def test_mesh_scan_engine_parity():
    """The scanned epoch engine composes with the shard_map core: under the
    mesh it is bit-identical to the host-loop engine AND mesh-size-invariant
    ((1,) vs (8,) under scan blocks), with the dataset and epoch index plan
    row-sharded over the data axis."""
    _run("""
a = run((8,), engine="scan")
b = run((8,), engine="host")
assert_bit_identical(a, b, "scan-vs-host-mesh")
c = run((1,), engine="scan")
assert_bit_identical(a, c, "scan-mesh-size")
from repro.train.engines import ScanEpochEngine
assert isinstance(make_trainer((8,), engine="scan").engine, ScanEpochEngine)
# scanned fused epochs keep the O(1) host-sync contract under the mesh too
assert all(r["host_syncs"] == 1 for r in a[0]), a[0]
print("MESH_OK")
""")


def test_mesh_grad_allreduce_psum():
    """grad_allreduce="psum" (the fast O(params) all-reduce) converges and
    tracks the fold; the default stays the chunk-major fold, bit-identical
    to an explicit grad_allreduce="fold"."""
    _run("""
fold_default = run((8,))
fold_explicit = run((8,), grad_allreduce="fold")
assert_bit_identical(fold_default, fold_explicit, "fold-default")
psum = run((8,), grad_allreduce="psum")
lp = [r["loss"] for r in psum[0]]
lf = [r["loss"] for r in fold_default[0]]
assert lp[-1] < lp[0], lp                        # converges
assert np.allclose(lp, lf, rtol=0.1), (lp, lf)   # tracks the fold
# psum is reproducible at a fixed mesh size
psum2 = run((8,), grad_allreduce="psum")
assert_bit_identical(psum, psum2, "psum-repro")
print("MESH_OK")
""")


def test_mesh_other_strategies_smoke():
    """Every strategy trains under the mesh — PlanOps plans replicate their
    score inputs inside the jitted plan step, so no strategy needs special
    mesh wiring."""
    _run("""
for strat in ("baseline", "infobatch", "sb"):
    recs, _ = run((8,), strategy=strat)
    losses = [r["loss"] for r in recs]
    assert losses[-1] < losses[0], (strat, losses)
print("MESH_OK")
""")


@pytest.mark.parametrize("strategy", ["iswr", "infobatch", "forget", "sb"])
def test_mesh_planops_strategies_size_invariant(strategy):
    """(1,) vs (8,) meshes for the newly device-planned strategies: epoch
    orders, per-epoch losses and final params bit-identical — the PlanOps
    plan steps replicate their score inputs, so the plan math is the exact
    single-device computation on every shard (and SB's in-step fused select
    draws from a replicated history + key)."""
    _run(f"""
a = run((1,), strategy={strategy!r}, epochs=4)
b = run((8,), strategy={strategy!r}, epochs=4)
assert_bit_identical(a, b, {strategy!r})
# device planning keeps the 1-host-sync/epoch contract under the mesh
assert all(r["host_syncs"] == 1 for r in a[0]), a[0]
assert all(r["host_syncs"] == 1 for r in b[0]), b[0]
print("MESH_OK")
""")


def test_mesh_fused_scoring_size_invariant():
    """(1,) vs (8,) meshes with TrainConfig.fused_scoring=True: the one-pass
    fused (loss, PA, PC) scoring rides the chunk-major fold like any
    loss_fn, so masks, orders, losses and final params stay bit-identical
    across mesh sizes — and the 1-host-sync/epoch contract holds."""
    _run("""
a = run((1,), fused_scoring=True)
b = run((8,), fused_scoring=True)
assert_bit_identical(a, b, "fused-scoring")
assert all(r["host_syncs"] == 1 for r in a[0]), a[0]
assert all(r["host_syncs"] == 1 for r in b[0]), b[0]
assert len(a[0][-1]["hidden"]) > 0
print("MESH_OK")
""")


def test_mesh_config_validation():
    """Bad mesh/chunk combinations fail fast with actionable errors."""
    _run("""
ds = SyntheticClassification(num_samples=512, image_size=8, seed=0)
tc = TrainConfig(mesh_shape=(8,), grad_chunks=4, batch_size=64)
try:
    Trainer(tc, lambda r: cnn.init(r, MODEL), loss_fn, ds, None)
except ValueError as e:
    assert "grad_chunks" in str(e)
else:
    raise AssertionError("grad_chunks=4 on an 8-mesh should fail")
tc = TrainConfig(mesh_shape=(8,), grad_chunks=8, batch_size=60)
try:
    Trainer(tc, lambda r: cnn.init(r, MODEL), loss_fn, ds, None)
except ValueError as e:
    assert "batch_size" in str(e)
else:
    raise AssertionError("batch_size%grad_chunks!=0 should fail")
tc = TrainConfig(mesh_shape=(8,), grad_allreduce="mean")
try:
    Trainer(tc, lambda r: cnn.init(r, MODEL), loss_fn, ds, None)
except ValueError as e:
    assert "grad_allreduce" in str(e)
else:
    raise AssertionError("grad_allreduce='mean' should fail")
from repro.core import make_strategy
from repro.launch.mesh import data_parallel_ctx
try:
    make_strategy("kakurenbo", 500, seed=0, ctx=data_parallel_ctx(8))
except ValueError as e:
    assert "row-shard" in str(e)
else:
    raise AssertionError("N=500 not divisible by 8 should fail")
print("MESH_OK")
""")
