"""Fused in-step scoring + rank-select routing: the kernel hot-path bars.

Three contracts from the fused-kernels PR:

- ``ops.fused_loss_metrics`` (one streaming online-softmax pass, analytic
  vjp) matches the three-pass jnp oracle — values AND gradients — on
  degenerate shapes: T not a multiple of the token block, V not a multiple
  of the vocab block, gold labels sitting exactly on vocab-tile boundaries,
  and kernel padding rows;
- ``TrainConfig.fused_scoring`` trains bit-identically across epoch engines
  and fails fast without a ``logits_fn``;
- the radix count-then-select behind ``planops.topk_hide`` /
  ``planops.sort_high_mask`` is bit-identical to the stable-argsort oracles
  it replaced (ties, both tails, kernel and jnp paths), and the backend
  probe honours the ``REPRO_PALLAS_INTERPRET`` override.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KakurenboConfig, LRSchedule, planops
from repro.data import SyntheticClassification
from repro.kernels import backend, ops, ref
from repro.kernels.threshold_select import rank_select_mask
from repro.models import cnn


def _logits(t, v, seed=0, scale=3.0):
    r = np.random.default_rng(seed)
    lg = jnp.asarray(r.normal(size=(t, v)) * scale, jnp.float32)
    lab = jnp.asarray(r.integers(0, v, t), jnp.int32)
    return lg, lab


# ---------------------------------------------------------------------------
# fused_loss_metrics: forward parity on degenerate shapes
# ---------------------------------------------------------------------------


# blk_t=256, blk_v=2048 in ops._padded_kernel_metrics: cover non-multiples of
# both, tiny shapes, and exact block multiples.
SHAPES = [(64, 512), (100, 1000), (256, 2048), (300, 2049), (7, 33)]


@pytest.mark.parametrize("t,v", SHAPES)
@pytest.mark.parametrize("scoring", ["reference", "kernel"])
def test_fused_matches_three_pass_oracle(t, v, scoring):
    lg, lab = _logits(t, v)
    ce, pa, pc = ops.fused_loss_metrics(lg, lab, scoring=scoring)
    ce_o, pa_o, pc_o = ref.loss_confidence_ref(lg, lab)
    np.testing.assert_allclose(np.asarray(ce), np.asarray(ce_o),
                               rtol=1e-4, atol=1e-5)
    assert (np.asarray(pa) == np.asarray(pa_o)).all()
    np.testing.assert_allclose(np.asarray(pc), np.asarray(pc_o),
                               rtol=1e-4, atol=1e-6)


def test_fused_boundary_gold_labels():
    """Gold labels on vocab-tile edges (0, blk_v-1, blk_v, V-1): the kernel's
    per-tile one-hot gather must pick them up in whichever tile they land."""
    t, v = 256, 4096          # exactly 2 vocab tiles of blk_v=2048
    lg, _ = _logits(t, v, seed=1)
    edges = [0, 2047, 2048, 4095]
    lab = jnp.asarray([edges[i % 4] for i in range(t)], jnp.int32)
    for scoring in ("reference", "kernel"):
        ce, pa, pc = ops.fused_loss_metrics(lg, lab, scoring=scoring)
        ce_o, pa_o, pc_o = ref.loss_confidence_ref(lg, lab)
        np.testing.assert_allclose(np.asarray(ce), np.asarray(ce_o),
                                   rtol=1e-4, atol=1e-5)
        assert (np.asarray(pa) == np.asarray(pa_o)).all()


def test_fused_kernel_padding_rows_are_invisible():
    """T % blk_t != 0 pads the kernel grid with zero rows; the sliced
    outputs must equal an unpadded run of the same rows."""
    lg, lab = _logits(300, 512, seed=2)   # pads to 512 rows internally
    full = ops.fused_loss_metrics(lg, lab, scoring="kernel")
    half = ops.fused_loss_metrics(lg[:100], lab[:100], scoring="kernel")
    for a, b in zip(half, full):
        assert (np.asarray(a) == np.asarray(b)[:100]).all()


def test_fused_scoring_rejects_unknown_backend():
    lg, lab = _logits(8, 16)
    with pytest.raises(ValueError, match="scoring"):
        ops.fused_loss_metrics(lg, lab, scoring="magic")


# ---------------------------------------------------------------------------
# fused_loss_metrics: the analytic vjp
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scoring", ["reference", "kernel"])
def test_fused_grad_matches_autodiff_oracle(scoring):
    lg, lab = _logits(64, 1000, seed=3)
    w = jnp.asarray(np.random.default_rng(4).random(64), jnp.float32)

    def fused(a):
        return jnp.mean(ops.fused_loss_metrics(a, lab, scoring=scoring)[0]
                        * w)

    def oracle(a):
        return jnp.mean(ref.loss_confidence_ref(a, lab)[0] * w)

    g_f = jax.grad(fused)(lg)
    g_o = jax.grad(oracle)(lg)
    np.testing.assert_allclose(np.asarray(g_f), np.asarray(g_o),
                               rtol=1e-4, atol=1e-6)


def test_fused_grad_composes_with_jit_and_aux():
    """value_and_grad(has_aux=True) through the custom_vjp inside jit — the
    exact shape the train step uses (int labels take a float0 cotangent)."""
    lg, lab = _logits(32, 100, seed=5)

    @jax.jit
    def step(a):
        def f(a_):
            ce, pa, pc = ops.fused_loss_metrics(a_, lab)
            return jnp.mean(ce), (ce, pa, pc)
        return jax.value_and_grad(f, has_aux=True)(a)

    (scalar, (ce, pa, pc)), g = step(lg)
    assert np.isfinite(float(scalar))
    assert g.shape == lg.shape and np.isfinite(np.asarray(g)).all()
    # softmax-minus-onehot rows sum to ~0 under a uniform mean weighting
    assert abs(float(jnp.sum(g))) < 1e-4


# ---------------------------------------------------------------------------
# TrainConfig.fused_scoring: trainer integration
# ---------------------------------------------------------------------------


MODEL = cnn.CNNConfig(image_size=8, widths=(8,), hidden=16)


def _trainer(engine, fused, epochs=2):
    from repro.train import Trainer, TrainConfig

    def logits_fn(params, batch):
        return cnn.forward(params, MODEL, batch["images"])

    def loss_fn(params, batch):
        logits = logits_fn(params, batch)
        loss, pa, pc = cnn.per_sample_metrics(logits, batch["labels"])
        w = batch.get("weight")
        scalar = jnp.mean(loss * w) if w is not None else jnp.mean(loss)
        return scalar, (loss, pa, pc)

    tc = TrainConfig(
        epochs=epochs, batch_size=64, strategy="kakurenbo", engine=engine,
        kakurenbo=KakurenboConfig(selection="histogram", max_fraction=0.3,
                                  fraction_milestones=(0, 1, 2, 3)),
        lr=LRSchedule(0.05, "cosine", epochs, 1), seed=0,
        fused_scoring=fused)
    ds = SyntheticClassification(num_samples=256, image_size=8, seed=0)
    return Trainer(tc, lambda r: cnn.init(r, MODEL),
                   None if fused else loss_fn, ds, None,
                   logits_fn=logits_fn)


def test_fused_scoring_scan_vs_host_bit_identical():
    th = _trainer("host", fused=True)
    hh = th.run()
    ts = _trainer("scan", fused=True)
    hs = ts.run()
    assert [h.train_loss for h in hh] == [h.train_loss for h in hs]
    for a, b in zip(jax.tree.leaves(th.params), jax.tree.leaves(ts.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # the scoring swap keeps the scanned engine + 1 host sync/epoch contract
    assert all(h.engine == "scan" and h.host_syncs == 1 for h in hs)


def test_fused_scoring_tracks_jnp_scoring():
    """Fused and jnp scoring may differ in reduction order (not bit-equal)
    but must train to numerically indistinguishable trajectories."""
    lf = [h.train_loss for h in _trainer("scan", fused=True).run()]
    lj = [h.train_loss for h in _trainer("scan", fused=False).run()]
    np.testing.assert_allclose(lf, lj, rtol=1e-4)


def test_fused_scoring_requires_logits_fn():
    from repro.train import Trainer, TrainConfig
    ds = SyntheticClassification(num_samples=64, image_size=8, seed=0)
    tc = TrainConfig(fused_scoring=True)
    with pytest.raises(ValueError, match="logits_fn"):
        Trainer(tc, lambda r: cnn.init(r, MODEL), None, ds, None)
    with pytest.raises(ValueError, match="loss_fn"):
        Trainer(TrainConfig(), lambda r: cnn.init(r, MODEL), None, ds, None)


# ---------------------------------------------------------------------------
# rank-select routing: bit-identity with the argsort oracles
# ---------------------------------------------------------------------------


DISTS = {
    "exp": lambda r, n: r.exponential(1, n),
    "ties": lambda r, n: np.round(r.exponential(1, n), 1),
    "constant": lambda r, n: np.full(n, 3.5),
    "negative": lambda r, n: np.linspace(-5, 5, n),
    "zeros": lambda r, n: np.where(r.random(n) < 0.3, -0.0, 0.0),
}


@pytest.mark.parametrize("dist", sorted(DISTS))
def test_topk_hide_matches_stable_rank_oracle(dist):
    n = 1000
    scores = jnp.asarray(DISTS[dist](np.random.default_rng(0), n),
                         jnp.float32)
    rank = np.asarray(planops.stable_rank_order(scores))
    for k in (0, 1, n // 3, n, n + 5):
        got = np.asarray(planops.topk_hide(scores, jnp.int32(k)))
        assert (got == (rank < k)).all(), (dist, k)


@pytest.mark.parametrize("dist", sorted(DISTS))
def test_sort_high_mask_matches_argsort_oracle(dist):
    n = 1000
    r = np.random.default_rng(1)
    loss = jnp.asarray(DISTS[dist](r, n), jnp.float32)
    valid = jnp.asarray(r.random(n) < 0.8)
    for frac in (0.0, 0.1, 0.5, 1.0):
        got = np.asarray(planops.sort_high_mask(loss, valid, frac))
        want = np.asarray(planops.sort_high_mask_argsort(loss, valid, frac))
        assert (got == want).all(), (dist, frac)


def test_sort_high_mask_nan_and_inf_stay_out_of_top():
    loss = jnp.asarray([1.0, np.nan, np.inf, 2.0, -np.inf, 3.0], jnp.float32)
    valid = jnp.ones(6, bool)
    got = np.asarray(planops.sort_high_mask(loss, valid, 0.5))
    want = np.asarray(planops.sort_high_mask_argsort(loss, valid, 0.5))
    assert (got == want).all()
    assert not got[1]          # NaN is invalid, never in the drop window


@pytest.mark.parametrize("n", [256, 777])
@pytest.mark.parametrize("high", [False, True])
def test_rank_select_kernel_path_matches_jnp_path(n, high):
    """The Pallas histogram/select kernels (interpret) against the pure-jnp
    radix twin — including N not a multiple of the block."""
    scores = jnp.asarray(
        np.round(np.random.default_rng(2).exponential(1, n), 1), jnp.float32)
    for k in (0, 1, n // 2, n):
        a = np.asarray(rank_select_mask(scores, jnp.int32(k), high=high,
                                        use_kernel=False))
        b = np.asarray(rank_select_mask(scores, jnp.int32(k), high=high,
                                        use_kernel=True, blk_n=256,
                                        interpret=True))
        assert (a == b).all(), (n, high, k)


# ---------------------------------------------------------------------------
# backend probe
# ---------------------------------------------------------------------------


def test_backend_probe_env_override(monkeypatch):
    try:
        monkeypatch.setenv(backend.ENV_VAR, "0")
        backend.probe_cache_clear()
        assert backend.use_interpret() is False
        assert backend.backend_name() == "pallas"
        assert backend.scoring_backend() == "kernel"
        monkeypatch.setenv(backend.ENV_VAR, "1")
        backend.probe_cache_clear()
        assert backend.use_interpret() is True
        assert backend.backend_name() == "interpret"
        assert backend.scoring_backend() == "reference"
        monkeypatch.delenv(backend.ENV_VAR)
        backend.probe_cache_clear()
        # unset: probe the jax backend (not a TPU in this container)
        assert backend.use_interpret() is (jax.default_backend() != "tpu")
    finally:
        backend.probe_cache_clear()


def test_resolve_explicit_wins_over_probe(monkeypatch):
    try:
        monkeypatch.setenv(backend.ENV_VAR, "0")
        backend.probe_cache_clear()
        assert backend.resolve(None) is False
        assert backend.resolve(True) is True
    finally:
        backend.probe_cache_clear()
