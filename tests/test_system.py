"""End-to-end behaviour tests for the KAKURENBO system."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import KakurenboConfig, LRSchedule
from repro.data import SyntheticClassification, SyntheticLM
from repro.models import cnn, build_model
from repro.configs.registry import get_arch
from repro.train import Trainer, TrainConfig

CFG_MODEL = cnn.CNNConfig(image_size=8, widths=(8, 16), hidden=32)


def _cnn_fns():
    def init_params(rng):
        return cnn.init(rng, CFG_MODEL)

    def loss_fn(params, batch):
        logits = cnn.forward(params, CFG_MODEL, batch["images"])
        loss, pa, pc = cnn.per_sample_metrics(logits, batch["labels"])
        w = batch.get("weight")
        scalar = jnp.mean(loss * w) if w is not None else jnp.mean(loss)
        return scalar, (loss, pa, pc)

    return init_params, loss_fn


def test_kakurenbo_reduces_work_and_learns():
    """KAKURENBO trains to a sane accuracy while doing measurably less
    backward work than the baseline — the paper's core claim in miniature."""
    ds = SyntheticClassification(num_samples=512, image_size=8, seed=0)
    test = ds.test_split(256)
    init_params, loss_fn = _cnn_fns()
    res = {}
    for strat in ("baseline", "kakurenbo"):
        tc = TrainConfig(
            epochs=10, batch_size=64, strategy=strat,
            lr=LRSchedule(0.05, "cosine", 10, 1),
            kakurenbo=KakurenboConfig(max_fraction=0.3,
                                      fraction_milestones=(0, 4, 7, 9)))
        tr = Trainer(tc, init_params, loss_fn, ds, test)
        hist = tr.run()
        res[strat] = (hist[-1].test_acc, sum(h.bwd_samples for h in hist))
    acc_b, work_b = res["baseline"]
    acc_k, work_k = res["kakurenbo"]
    assert work_k < work_b                      # strictly less backward work
    assert acc_k > acc_b - 0.15                 # accuracy in the same regime
    assert acc_k > 0.3                          # actually learned


def test_kakurenbo_hiding_follows_difficulty():
    """Easy (low-difficulty) samples get hidden more than hard ones."""
    ds = SyntheticClassification(num_samples=512, image_size=8, seed=0)
    init_params, loss_fn = _cnn_fns()
    tc = TrainConfig(epochs=8, batch_size=64, strategy="kakurenbo",
                     lr=LRSchedule(0.05, "cosine", 8, 1),
                     kakurenbo=KakurenboConfig(max_fraction=0.4,
                                               fraction_milestones=(0, 8, 9, 10)))
    tr = Trainer(tc, init_params, loss_fn, ds, None)
    hidden_count = np.zeros(512)
    for e in range(8):
        stats = tr.run_epoch(e)
        hidden_count[np.asarray(tr.sampler.state.hidden)] += 1
    easy = ds.difficulty < 0.3
    if hidden_count.sum() > 0:
        assert hidden_count[easy].mean() >= hidden_count[~easy].mean()


def test_lm_training_with_kakurenbo():
    """Sequence-level hiding on a reduced LM arch (smollm family)."""
    cfg = get_arch("smollm-135m").reduced()
    model = build_model(cfg)
    # unigram-table source, small effective vocab: learnable in a few epochs
    ds = SyntheticLM(num_samples=128, seq_len=32, vocab_size=48, order=1,
                     easy_fraction=0.7, seed=0)

    def init_params(rng):
        return model.init(rng)

    def loss_fn(params, batch):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        return model.loss_and_metrics(params, b)

    tc = TrainConfig(epochs=8, batch_size=32, strategy="kakurenbo",
                     optimizer="adamw", optimizer_hp={},
                     lr=LRSchedule(1e-2, "cosine", 8, 1),
                     kakurenbo=KakurenboConfig(max_fraction=0.3,
                                               fraction_milestones=(0, 4, 6, 8)))
    tr = Trainer(tc, init_params, loss_fn, ds, None)
    hist = tr.run()
    assert hist[-1].train_loss < hist[0].train_loss
    assert any(h.hidden_fraction > 0 for h in hist)
