"""End-to-end LM pretraining driver with KAKURENBO sequence hiding.

    PYTHONPATH=src python examples/lm_train.py --steps 200        # reduced
    PYTHONPATH=src python examples/lm_train.py --arch smollm-135m --full

Trains a registry architecture (reduced config by default — the full
smollm-135m is the ~100M-class target on real hardware; on this CPU
container the reduced config keeps the example to minutes) for a few hundred
steps on the synthetic LM corpus, with per-epoch KAKURENBO hiding, async
checkpointing and restart support.
"""
import argparse

import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.core import KakurenboConfig, LRSchedule
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train import Trainer, TrainConfig


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--full", action="store_true")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=32)
    p.add_argument("--seq-len", type=int, default=32)
    p.add_argument("--num-samples", type=int, default=512)
    p.add_argument("--strategy", default="kakurenbo")
    p.add_argument("--ckpt-dir", default="results/lm_train_ckpt")
    p.add_argument("--resume", action="store_true")
    args = p.parse_args()

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    ds = SyntheticLM(num_samples=args.num_samples, seq_len=args.seq_len,
                     vocab_size=min(cfg.vocab_size, 64), order=1,
                     easy_fraction=0.7, seed=0)
    steps_per_epoch = args.num_samples // args.batch
    epochs = max(args.steps // steps_per_epoch, 1)

    def loss_fn(params, batch):
        return model.loss_and_metrics(
            params, {k: jnp.asarray(v) for k, v in batch.items()})

    tc = TrainConfig(
        epochs=epochs, batch_size=args.batch, strategy=args.strategy,
        optimizer="adamw", optimizer_hp={},
        lr=LRSchedule(1e-2, "cosine", epochs, 1),
        kakurenbo=KakurenboConfig(
            max_fraction=0.3,
            fraction_milestones=(0, epochs // 3, epochs // 2,
                                 3 * epochs // 4)),
        checkpoint_dir=args.ckpt_dir, checkpoint_every=max(epochs // 4, 1))
    tr = Trainer(tc, lambda rng: model.init(rng), loss_fn, ds, None)
    if args.resume and tr.restore_latest():
        print(f"resumed from epoch {tr.epoch}")
    hist = tr.run()
    total_steps = sum(h.bwd_samples for h in hist) // args.batch
    print(f"\narch={cfg.name} ({'full' if args.full else 'reduced'}) "
          f"epochs={epochs} sgd_steps={total_steps}")
    for h in hist:
        print(f"epoch {h.epoch}: loss={h.train_loss:.3f} "
              f"F*={h.hidden_fraction:.3f} lr={h.lr:.4f} "
              f"wall={h.wall_time:.1f}s")


if __name__ == "__main__":
    main()
