"""Quickstart: KAKURENBO vs the baseline on a small classification task.

    PYTHONPATH=src python examples/quickstart.py

Trains the paper's model family (small CNN) on the synthetic easy/hard
dataset twice — uniform baseline and KAKURENBO — and prints the accuracy
and backward-work comparison (the paper's core claim in one screen).
"""
import jax.numpy as jnp

from repro.core import KakurenboConfig, LRSchedule, make_strategy
from repro.data import SyntheticClassification
from repro.models import cnn
from repro.train import Trainer, TrainConfig

EPOCHS = 12
MODEL = cnn.CNNConfig(image_size=16, widths=(16, 32), hidden=64)


def loss_fn(params, batch):
    logits = cnn.forward(params, MODEL, batch["images"])
    loss, pa, pc = cnn.per_sample_metrics(logits, batch["labels"])
    w = batch.get("weight")
    scalar = jnp.mean(loss * w) if w is not None else jnp.mean(loss)
    return scalar, (loss, pa, pc)


def main() -> None:
    ds = SyntheticClassification(num_samples=1024, seed=0)
    test = ds.test_split(512)
    results = {}
    kc = KakurenboConfig(max_fraction=0.3, fraction_milestones=(0, 4, 6, 9))
    for strategy in ("baseline", "kakurenbo"):
        tc = TrainConfig(
            epochs=EPOCHS, batch_size=128, strategy=strategy,
            lr=LRSchedule(0.05, "cosine", EPOCHS, 1), kakurenbo=kc)
        # Strategies come from the registry; any @register_strategy name
        # (iswr, sb, infobatch, ...) drops in here unchanged.
        strat = make_strategy(strategy, ds.num_samples, cfg=kc)
        tr = Trainer(tc, lambda rng: cnn.init(rng, MODEL), loss_fn, ds, test,
                     strategy=strat)
        hist = tr.run()
        results[strategy] = (hist[-1].test_acc,
                             sum(h.bwd_samples for h in hist),
                             sum(h.wall_time for h in hist))
        print(f"[{strategy}] per-epoch: " + " ".join(
            f"e{h.epoch}:acc={h.test_acc:.2f},F*={h.hidden_fraction:.2f}"
            for h in hist[::3]))
    (acc_b, bwd_b, t_b), (acc_k, bwd_k, t_k) = (results["baseline"],
                                                results["kakurenbo"])
    print(f"\nbaseline : acc={acc_b:.3f}  bwd_samples={bwd_b}  wall={t_b:.1f}s")
    print(f"kakurenbo: acc={acc_k:.3f}  bwd_samples={bwd_k}  wall={t_k:.1f}s")
    print(f"backward work saved: {1 - bwd_k / bwd_b:.1%}  "
          f"accuracy delta: {acc_k - acc_b:+.3f}")


if __name__ == "__main__":
    main()
