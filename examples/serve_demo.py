"""Batched serving example: prefill + decode on any registry arch.

    PYTHONPATH=src python examples/serve_demo.py --arch mamba2-130m
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
