"""Paper reproduction driver: Tables 2/5/6 + Figs 2/4 in one run.

    PYTHONPATH=src python examples/paper_reproduction.py [--quick]
    PYTHONPATH=src python examples/paper_reproduction.py --list-strategies

Delegates to the benchmark modules (one per paper table/figure) and writes
results/paper_reproduction.csv.  Every table row is a registered
``SampleStrategy`` name — ``--list-strategies`` prints the registry.
"""
import argparse
import contextlib
import io
import os
import sys

# Allow `python examples/paper_reproduction.py` from the repo root: the
# interpreter puts examples/ on sys.path, not the root that holds
# benchmarks/ nor src/ that holds repro/.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.core import STRATEGIES, available_strategies, make_strategy

from benchmarks import (fig2_speedup, fig4_fraction, selection_overhead,
                        table2_accuracy, table3_gradmatch, table5_tau,
                        table6_ablation)


def list_strategies() -> None:
    for name in available_strategies():
        cls = STRATEGIES[name]
        cfg = cls.config_cls.__name__ if cls.config_cls else "-"
        # Smoke-build each one so the listing doubles as a registry check.
        make_strategy(name, 8, seed=0)
        print(f"{name:>10}  {cls.__name__:<20} config={cfg}")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="only Table 2 + Fig. 4 (fast)")
    p.add_argument("--list-strategies", action="store_true",
                   help="print the sample-strategy registry and exit")
    p.add_argument("--out", default="results/paper_reproduction.csv")
    args = p.parse_args()
    if args.list_strategies:
        list_strategies()
        return
    sections = ([table2_accuracy, fig4_fraction] if args.quick else
                [table2_accuracy, table3_gradmatch, table5_tau,
                 table6_ablation, fig2_speedup, fig4_fraction,
                 selection_overhead])
    buf = io.StringIO()
    print("name,us_per_call,derived")
    buf.write("name,us_per_call,derived\n")
    for mod in sections:
        out = io.StringIO()
        with contextlib.redirect_stdout(out):
            mod.main()
        text = out.getvalue()
        print(text, end="")
        buf.write(text)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(buf.getvalue())
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
