"""Paper reproduction driver: Tables 2/5/6 + Figs 2/4 in one run.

    PYTHONPATH=src python examples/paper_reproduction.py [--quick]

Delegates to the benchmark modules (one per paper table/figure) and writes
results/paper_reproduction.csv.
"""
import argparse
import contextlib
import io
import os

from benchmarks import (fig2_speedup, fig4_fraction, selection_overhead,
                        table2_accuracy, table3_gradmatch, table5_tau,
                        table6_ablation)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="only Table 2 + Fig. 4 (fast)")
    p.add_argument("--out", default="results/paper_reproduction.csv")
    args = p.parse_args()
    sections = ([table2_accuracy, fig4_fraction] if args.quick else
                [table2_accuracy, table3_gradmatch, table5_tau,
                 table6_ablation, fig2_speedup, fig4_fraction,
                 selection_overhead])
    buf = io.StringIO()
    print("name,us_per_call,derived")
    buf.write("name,us_per_call,derived\n")
    for mod in sections:
        out = io.StringIO()
        with contextlib.redirect_stdout(out):
            mod.main()
        text = out.getvalue()
        print(text, end="")
        buf.write(text)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(buf.getvalue())
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
